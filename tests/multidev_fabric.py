"""Cross-fabric parity matrix on a real (2 data x 4 model) mesh.

Run in a subprocess with 8 emulated devices (see test_multidevice.py).
Every registered fabric executes the SAME routing problem through the
one MoE pipeline; with generous capacity and a plan derived from the
actual traffic, values, grads, and the ``{routing, dropped}`` stats
contract must agree across all of them — the registry's core promise
(backends may only differ in movement and padding bytes).  The traced
backends (phase_pipelined, ragged_a2a) must additionally swap re-planned
tables into the SAME executable (zero recompiles).
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.layers as layers

layers.COMPUTE_DTYPE = jnp.float32  # exact equivalence, not bf16 rounding

from repro.configs.base import ModelConfig, MoECfg
from repro.core import (
    HierarchicalTable,
    ScheduleTable,
    decompose,
    hierarchical_decompose,
    hierarchical_plan,
    plan_schedule,
)
from repro.models import moe
from repro.parallel import axis_rules
from repro.parallel.fabric import fabric_names

N_EP = 4


def make_cfg(dispatch: str, pod_size: int = 2, wire_dtype: str = "bf16") -> ModelConfig:
    return ModelConfig(
        name=f"fabric-{dispatch}",
        family="moe",
        n_layers=1,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=97,
        moe=MoECfg(
            n_experts=8,
            top_k=2,
            d_ff_expert=48,
            capacity_factor=8.0,  # generous: no drops -> exact equivalence
            dispatch=dispatch,
            pod_size=pod_size,
            wire_dtype=wire_dtype,
        ),
    )


def traffic_from_routing(params, cfg, x, n):
    """Host-side replication of the EP path's routing -> traffic matrix."""
    t = x.shape[0] * x.shape[1]
    t_ep = t // n
    e_local = cfg.moe.n_experts // n
    xf = x.reshape(t, -1)
    mat = np.zeros((n, n))
    for i in range(n):
        chunk = xf[i * t_ep : (i + 1) * t_ep]
        idx, _ = moe._router(params, cfg, chunk)
        dest = np.asarray(idx // e_local).ravel()
        for ddev in dest:
            mat[i, ddev] += 1
    return mat


def main() -> None:
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    cfg0 = make_cfg("dense")
    params = moe.moe_init(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg0.d_model), jnp.float32)

    with axis_rules(mesh):
        traffic = traffic_from_routing(params, cfg0, x, N_EP)
        sched = plan_schedule(
            decompose(traffic, "maxweight"), slack=1.5, quantum=8,
        )
        table = ScheduleTable.from_schedules(
            [sched], k_max=N_EP, clip=True, envelope="auto"
        )
        htab = hierarchical_plan(traffic, 2, n_layers=1, slack=1.5, quantum=8)
        schedule_for = {
            "dense": None,
            "a2a": None,
            "ppermute": sched,
            "phase_pipelined": table.row(0),
            "ragged_a2a": table.row(0),
            "hierarchical": htab.row(0),
        }
        missing = set(fabric_names()) - set(schedule_for)
        assert not missing, f"parity matrix must cover new fabrics: {missing}"

        results = {}
        for name, schedule in schedule_for.items():
            cfg = make_cfg(name)
            # static A2ASchedules ride the closure (the ppermute
            # contract: plans are baked in); rows could be traced args
            y, stats = jax.jit(
                lambda p, x, cfg=cfg, s=schedule: moe.moe_apply(
                    p, cfg, x, schedule=s, return_stats=True
                )
            )(params, x)
            g = jax.jit(
                jax.grad(
                    lambda p, x, cfg=cfg, s=schedule: (
                        moe.moe_apply(p, cfg, x, schedule=s) ** 2
                    ).sum()
                )
            )(params, x)
            results[name] = (np.asarray(y), stats, g)
            print(f"ran {name}")

        y_ref, st_ref, g_ref = results["dense"]
        # dense is the single-row-stats oracle; EP stats fold to [n, E]
        ref_routing = np.asarray(st_ref["routing"]).sum(axis=0)
        for name, (y, st, g) in results.items():
            np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)
            assert set(st) == {"routing", "dropped"}, (name, set(st))
            np.testing.assert_allclose(
                np.asarray(st["routing"]).sum(axis=0), ref_routing,
                rtol=1e-6, atol=1e-6,
            )
            assert float(np.asarray(st["dropped"]).sum()) == 0.0, name
            for ga, gr in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
                np.testing.assert_allclose(
                    np.asarray(ga), np.asarray(gr), rtol=2e-4, atol=2e-4
                )
            print(f"OK {name}: values + grads + stats == dense")

        # traced backends: re-planned tables swap with zero recompiles
        for name in ("phase_pipelined", "ragged_a2a"):
            cfg = make_cfg(name)
            f = jax.jit(
                lambda p, x, r, cfg=cfg: moe.moe_apply(p, cfg, x, schedule=r)
            )
            f(params, x, table.row(0))
            alt = table.update(
                [
                    plan_schedule(
                        decompose(
                            traffic_from_routing(params, cfg0, x, N_EP) * 0.7,
                            "maxweight",
                        ),
                        slack=1.5, quantum=8,
                    )
                ]
            )
            f(params, x, alt.row(0))
            assert f._cache_size() == 1, f"{name} table swap recompiled"
            print(f"OK {name}: in-envelope table swap reused the executable")

        # --- the ragged transfer code itself (the primitive is absent in
        # this container's jax): stub jax.lax.ragged_all_to_all with a
        # reference implementation built on all_to_all, force-enable the
        # ragged path, and re-assert parity — this pins _ragged_send's
        # traced peer/size wiring, not just the emulation fallback.
        from repro.parallel.fabric import ragged_a2a as ra

        def ragged_ref(operand, output, input_offsets, send_sizes,
                       output_offsets, recv_sizes, *, axis_name):
            # the backend's usage contract: offsets all zero, at most one
            # nonzero send (my whole block) / recv per rank per phase
            n = send_sizes.shape[0]
            dst = jnp.argmax(send_sizes)
            sending = send_sizes.sum() > 0
            buf = (
                jnp.zeros((n, *operand.shape), operand.dtype)
                .at[dst]
                .add(jnp.where(sending, operand, 0))
            )
            got = jax.lax.all_to_all(
                buf, axis_name, split_axis=0, concat_axis=0, tiled=True
            ).sum(axis=0)
            receiving = recv_sizes.sum() > 0
            return jnp.where(receiving, got, output)

        old_ragged = ra._RAGGED
        ra._RAGGED = ragged_ref
        os.environ["REPRO_FORCE_RAGGED"] = "1"
        try:
            assert ra.ragged_available()
            cfg_r = make_cfg("ragged_a2a")
            y_r, st_r = jax.jit(
                lambda p, x, r: moe.moe_apply(
                    p, cfg_r, x, schedule=r, return_stats=True
                )
            )(params, x, table.row(0))
            np.testing.assert_allclose(
                np.asarray(y_r), y_ref, rtol=1e-5, atol=1e-5
            )
            assert float(np.asarray(st_r["dropped"]).sum()) == 0.0
        finally:
            ra._RAGGED = old_ragged
            os.environ.pop("REPRO_FORCE_RAGGED", None)
        print("OK ragged_a2a (stubbed ragged_all_to_all) == dense")

        # --- hierarchical, pod_size=4: one pod == all traffic intra (the
        # inter level is dark) — parity must still hold
        htab4 = hierarchical_plan(traffic, 4, n_layers=1, slack=1.5, quantum=8)
        cfg_h4 = make_cfg("hierarchical", pod_size=4)
        y4, st4 = jax.jit(
            lambda p, x, r: moe.moe_apply(
                p, cfg_h4, x, schedule=r, return_stats=True
            )
        )(params, x, htab4.row(0))
        np.testing.assert_allclose(np.asarray(y4), y_ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(st4["routing"]).sum(axis=0), ref_routing,
            rtol=1e-6, atol=1e-6,
        )
        assert float(np.asarray(st4["dropped"]).sum()) == 0.0
        print("OK hierarchical pod_size=4 (degenerate inter) == dense")

        # --- hierarchical dual-table swaps: an intra-only re-plan and a
        # both-level re-plan must each reuse the executable (per-level
        # envelopes are the static aux; updates keep them)
        cfg_h = make_cfg("hierarchical")
        fh = jax.jit(
            lambda p, x, r: moe.moe_apply(p, cfg_h, x, schedule=r)
        )
        fh(params, x, htab.row(0))
        i_d, e_d = hierarchical_decompose(traffic * 0.7, 2)
        alt_intra = htab.update(
            intra=htab.intra.update([plan_schedule(i_d, slack=1.5, quantum=8)])
        )
        fh(params, x, alt_intra.row(0))
        assert fh._cache_size() == 1, "intra-only table swap recompiled"
        alt_both = alt_intra.update(
            inter=htab.inter.update([plan_schedule(e_d, slack=1.5, quantum=8)])
        )
        fh(params, x, alt_both.row(0))
        assert fh._cache_size() == 1, "dual-table swap recompiled"
        print("OK hierarchical: intra-only + dual-table swaps reused the executable")

        # --- wire dtype crosses only the inter seam: with one pod (all
        # traffic intra-host) the fp8 codec must be a bit-exact no-op,
        # while with two pods the quantized inter slots shift the output
        # only within fp8 tolerance
        cfg_f4 = make_cfg("hierarchical", pod_size=4, wire_dtype="fp8")
        y4_f = jax.jit(
            lambda p, x, r: moe.moe_apply(p, cfg_f4, x, schedule=r)
        )(params, x, htab4.row(0))
        np.testing.assert_array_equal(np.asarray(y4_f), np.asarray(y4))
        cfg_f2 = make_cfg("hierarchical", pod_size=2, wire_dtype="fp8")
        y2_f, st2_f = jax.jit(
            lambda p, x, r: moe.moe_apply(
                p, cfg_f2, x, schedule=r, return_stats=True
            )
        )(params, x, htab.row(0))
        np.testing.assert_allclose(np.asarray(y2_f), y_ref, atol=0.25)
        np.testing.assert_allclose(
            np.asarray(st2_f["routing"]).sum(axis=0), ref_routing,
            rtol=1e-6, atol=1e-6,
        )
        print("OK hierarchical wire: intra bit-exact under fp8, inter within tolerance")

    print("ALL FABRIC MATRIX CHECKS PASSED")


if __name__ == "__main__":
    main()
