"""Two-level (pod-aware) decomposition: split, tables, planners, and the
composed drift controller (PR 9).

The fabric-facing side (parity matrix, wire seam, dispatch bytes) lives
in ``tests/test_fabric.py`` / ``tests/multidev_fabric.py``; this module
pins the core contracts those build on: the traffic partition, the
diagonal-exclusion invariant of the intra union decomposition, the
``HierarchicalTable`` pytree/merge algebra, the traced two-level
planner, and per-level re-plan independence.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ControllerConfig,
    HierarchicalRuntime,
    HierarchicalTable,
    check_pod_size,
    hierarchical_decompose,
    hierarchical_plan,
    hierarchical_plan_traced,
    same_pod_mask,
    simulate_hierarchical,
    split_traffic,
    split_traffic_traced,
)
from repro.core.cost_models import CommModel, ComputeModel

N = 4


def _traffic(seed: int = 0, n: int = N, scale: float = 300.0):
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) * scale
    np.fill_diagonal(m, 0)
    return m


def _leaves_equal(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


class TestPodSizeValidation:
    """Satellite 1: pod-size misuse raises a ValueError naming ``n``,
    the offending ``pod_size``, and the valid divisors."""

    def test_error_names_n_pod_size_and_divisors(self):
        with pytest.raises(ValueError) as e:
            check_pod_size(8, 3)
        msg = str(e.value)
        assert "pod_size=3" in msg and "n=8" in msg, msg
        for d in (1, 2, 4, 8):
            assert str(d) in msg, (d, msg)

    def test_valid_pod_size_returns_it(self):
        assert check_pod_size(8, 4) == 4
        assert check_pod_size(8, 1) == 1
        assert check_pod_size(8, 8) == 8

    @pytest.mark.parametrize("bad", (0, -2))
    def test_nonpositive_pod_size_rejected(self, bad):
        with pytest.raises(ValueError, match=f"pod_size={bad}"):
            check_pod_size(8, bad)

    def test_split_traffic_propagates(self):
        with pytest.raises(ValueError, match="pod_size=3"):
            split_traffic(np.zeros((8, 8)), 3)

    def test_fabric_validate_propagates(self):
        """The fabric's ``validate_schedule`` rejects a mis-sized table
        with the same divisor-naming error, prefixed by the backend."""
        from repro.parallel.fabric import get_fabric

        row = hierarchical_plan(_traffic(), 2, n_layers=1).row(0)
        bad = dataclasses.replace(row, pod_size=3)
        with pytest.raises(ValueError, match="hierarchical.*pod_size=3"):
            get_fabric("hierarchical").validate_schedule(bad, n=N)
        with pytest.raises(ValueError, match="pod_size=3"):
            get_fabric("dense").validate_schedule(bad, n=N)


class TestSplitTraffic:
    def test_partition_is_exact(self):
        m = _traffic()
        intra, inter = split_traffic(m, 2)
        np.testing.assert_array_equal(intra + inter, m)
        same = same_pod_mask(N, 2)
        assert (intra[~same] == 0).all()
        assert (inter[same] == 0).all()

    def test_batched_leading_dims(self):
        m = np.stack([_traffic(s) for s in range(6)]).reshape(2, 3, N, N)
        intra, inter = split_traffic(m, 2)
        assert intra.shape == inter.shape == (2, 3, N, N)
        np.testing.assert_array_equal(intra + inter, m)

    def test_traced_twin_matches_host(self):
        m = _traffic(3)
        intra, inter = split_traffic(m, 2)
        ti, te = jax.jit(lambda a: split_traffic_traced(a, 2))(
            jnp.asarray(m)
        )
        np.testing.assert_allclose(np.asarray(ti), intra, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(te), inter, rtol=1e-6)


class TestDiagonalExclusionInvariant:
    """Satellite 2: the union intra decomposition excludes local
    (diagonal) tokens — ``simulate_decomposition(local_tokens=...)``
    must never count them twice (the ``_union_pod_phases`` docstring
    points here)."""

    def setup_method(self):
        self.m = _traffic(1)
        # nonzero diagonal: local tokens the phases must NOT carry
        np.fill_diagonal(self.m, 50.0)
        self.intra_d, self.inter_d = hierarchical_decompose(self.m, 2)

    def test_union_matrix_has_zero_diagonal(self):
        np.testing.assert_array_equal(np.diag(self.intra_d.matrix), 0.0)
        np.testing.assert_array_equal(np.diag(self.inter_d.matrix), 0.0)

    def test_partition_conserves_demand(self):
        np.testing.assert_allclose(
            self.intra_d.matrix
            + self.inter_d.matrix
            + np.diag(np.diag(self.m)),
            self.m,
        )

    def test_no_phase_carries_local_tokens(self):
        for d, within_pod in ((self.intra_d, True), (self.inter_d, False)):
            st = d.stacked()
            src = np.arange(N)
            active = st.sent > 0
            assert not (active & (st.perms == src)).any(), d.strategy
            crosses = (src // 2)[None, :] != (st.perms // 2)
            if within_pod:  # intra circuits never leave the pod
                assert not (active & crosses).any()
            else:  # inter circuits always do
                assert (crosses | ~active).all()

    def test_phase_tokens_equal_offdiagonal_intra_mass(self):
        """Total phase traffic == intra off-diagonal demand, so feeding
        the diagonal back via ``local_tokens`` adds it exactly once."""
        intra, _ = split_traffic(self.m, 2)
        off = intra.copy()
        np.fill_diagonal(off, 0.0)
        st = self.intra_d.stacked()
        assert st.sent.sum() == pytest.approx(off.sum())

    def test_simulate_hierarchical_smoke(self):
        out = simulate_hierarchical(
            self.m, 2, ComputeModel(5.0, 0.01),
            CommModel(100.0, reconf_us=0.05),
            CommModel(25.0, reconf_us=15.0),
        )
        assert out["hier_us"] > 0 and out["flat_us"] > 0
        assert np.isfinite(out["speedup"])
        assert out["intra_phases"] > 0 and out["inter_phases"] > 0


class TestHierarchicalTable:
    def setup_method(self):
        self.m = np.stack([_traffic(s) for s in (0, 7)])
        self.tab = hierarchical_plan(self.m, 2)

    def test_shapes_and_layers(self):
        assert not self.tab.is_row
        assert self.tab.num_layers == 2
        assert self.tab.n == N
        assert self.tab.k_max == self.tab.intra.k_max + self.tab.inter.k_max
        row = self.tab.row(1)
        assert row.is_row and row.pod_size == 2

    def test_merged_folds_served_prefixes(self):
        row = self.tab.row(0)
        mr = row.merged()
        ki, ke = row.intra.k_max, row.inter.k_max
        assert mr.k_max == ki + ke
        assert int(mr.n_phases) == ki + ke  # constant: no live-slot gating
        caps = np.asarray(mr.caps)
        # slots past each child's served prefix fold to dead (cap 0)
        assert (caps[int(row.intra.n_phases):ki] == 0).all()
        assert (caps[ki + int(row.inter.n_phases):] == 0).all()
        # live slots keep the child caps
        np.testing.assert_array_equal(
            caps[: int(row.intra.n_phases)],
            np.asarray(row.intra.caps)[: int(row.intra.n_phases)],
        )

    def test_pair_caps_additive_over_levels(self):
        row = self.tab.row(0)
        total = np.asarray(row.pair_caps(2))
        np.testing.assert_array_equal(
            total,
            np.asarray(row.intra.pair_caps(2))
            + np.asarray(row.inter.pair_caps(2)),
        )
        np.testing.assert_array_equal(
            total, np.asarray(row.merged().pair_caps(2))
        )
        # each pair is served by exactly one level
        same = same_pod_mask(N, 2)
        assert (np.asarray(row.intra.pair_caps(2))[~same] == 0).all()
        assert (np.asarray(row.inter.pair_caps(2))[same] == 0).all()

    def test_update_swaps_one_level_in_place(self):
        from repro.core import decompose, plan_schedule

        i_d, _ = hierarchical_decompose(self.m[0] * 0.5, 2)
        alt = self.tab.update(
            intra=self.tab.intra.update([plan_schedule(i_d)] * 2)
        )
        assert alt.inter is self.tab.inter  # untouched object, not a copy
        assert alt.pod_size == self.tab.pod_size
        assert alt.intra.k_max == self.tab.intra.k_max

    def test_pytree_round_trip_keeps_static_aux(self):
        leaves, treedef = jax.tree_util.tree_flatten(self.tab)
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(back, HierarchicalTable)
        assert back.pod_size == self.tab.pod_size
        assert _leaves_equal(back, self.tab)


class TestTracedPlanner:
    def test_union_perms_are_pod_local_permutations(self):
        m = jnp.asarray(_traffic(5)[None])
        out = jax.jit(
            lambda a: hierarchical_plan_traced(
                a, 2, k_max_intra=2, k_max_inter=N
            )
        )(m)
        pod = np.arange(N) // 2
        src = np.arange(N)
        for level, kmax in (("intra", 2), ("inter", N)):
            perms = np.asarray(out[level]["perms"])[0]
            assert perms.shape == (kmax, N)
            for k, p in enumerate(perms):
                np.testing.assert_array_equal(
                    np.sort(p), src, err_msg=f"{level} phase {k}"
                )
            assert int(np.asarray(out[level]["n_phases"])[0]) <= kmax
        # the intra union never crosses pods, and no valid slot is local
        ip = np.asarray(out["intra"]["perms"])[0]
        iv = np.asarray(out["intra"]["valid"])[0]
        assert (pod[ip] == pod[None, :]).all()
        assert not (iv & (ip == src[None, :])).any()
        # every valid inter slot crosses the pod seam
        ep = np.asarray(out["inter"]["perms"])[0]
        ev = np.asarray(out["inter"]["valid"])[0]
        assert ((pod[ep] != pod[None, :]) | ~ev).all()

    def test_enough_phases_serve_the_whole_split(self):
        """With ``k_max`` = level width the greedy clears each level's
        split entirely: summed slot caps cover every demanded pair."""
        m = _traffic(9)
        out = hierarchical_plan_traced(
            jnp.asarray(m[None]), 2, k_max_intra=2, k_max_inter=N,
            quantum=1, min_cap=1,
        )
        intra, inter = split_traffic(m, 2)
        src = np.arange(N)
        for level, demand in (("intra", intra), ("inter", inter)):
            perms = np.asarray(out[level]["perms"])[0]
            valid = np.asarray(out[level]["valid"])[0]
            caps = np.asarray(out[level]["caps"])[0].astype(float)
            served = np.zeros((N, N))
            for k in range(perms.shape[0]):
                on = valid[k]
                served[src[on], perms[k][on]] += caps[k]
            assert (served + 1e-6 >= demand).all(), level


class TestRuntimeIndependence:
    """Intra drift must never force an inter re-plan (and vice versa);
    PR 6 link masks apply to exactly one level per dead pair."""

    def setup_method(self):
        self.m = _traffic(0)
        self.rt = HierarchicalRuntime(
            ControllerConfig(n_ranks=N, n_experts=8), 1, pod_size=2
        )
        self.rt.prime(self.m)

    def test_pod_size_validated_at_init(self):
        with pytest.raises(ValueError, match="pod_size=3"):
            HierarchicalRuntime(
                ControllerConfig(n_ranks=N, n_experts=8), 1, pod_size=3
            )

    def test_table_pairs_both_levels(self):
        tab = self.rt.table()
        assert isinstance(tab, HierarchicalTable)
        assert tab.pod_size == 2 and tab.num_layers == 1

    def test_intra_drift_leaves_inter_plan_untouched(self):
        inter0 = self.rt.inter_table()
        intra0 = self.rt.intra.table()
        inter_replans0 = self.rt.metrics()["replan_events"]
        intra, inter = split_traffic(self.m, 2)
        drift = np.where(
            same_pod_mask(N, 2), intra[::-1, ::-1].T * 4.0, inter
        )
        np.fill_diagonal(drift, 0)
        replanned = False
        for _ in range(8):
            replanned |= self.rt.observe_traffic(drift[None]).replanned
        assert replanned  # the drift was big enough to trip the intra EMA
        met = self.rt.metrics()
        assert met["replan_events"] == inter_replans0  # inter: no re-plan
        assert met["intra"]["replan_events"] > 1  # prime + drift
        assert _leaves_equal(self.rt.inter_table(), inter0)
        assert not _leaves_equal(self.rt.intra.table(), intra0)

    def test_link_masks_apply_per_level(self):
        # a dead SAME-pod link degrades only the electrical level
        mask = np.ones((N, N), bool)
        mask[0, 1] = mask[1, 0] = False
        self.rt.set_link_mask(mask)
        met = self.rt.metrics()
        assert met["intra"]["masked_replans"] == 1
        assert met["masked_replans"] == 0
        self.rt.set_link_mask(None)
        # a dead CROSS-pod link degrades only the circuit level
        mask = np.ones((N, N), bool)
        mask[0, 2] = mask[2, 0] = False
        self.rt.set_link_mask(mask)
        met = self.rt.metrics()
        assert met["intra"]["masked_replans"] == 1  # unchanged
        assert met["masked_replans"] == 1

    def test_metrics_nest_the_intra_level(self):
        met = self.rt.metrics()
        assert met["pod_size"] == 2
        assert "replan_events" in met["intra"]
