"""Degraded-fabric resilience (PR 6): fault scenarios, link-mask-aware
planning, the fault-injection wrapper fabric, and the chaos runs.

Three layers of guarantee, each asserted here:

* **Planning** — ``apply_link_mask`` conserves every row's demand while
  zeroing dark pairs, and masked ``decompose``/``decompose_batch`` plans
  never route a dead link (property-tested over random scenarios).
* **Correctness under faults** — a masked plan is still just a plan:
  ``moe_apply`` on a masked row must match the dense pair-caps oracle on
  values *and* grads with zero admitted-token drops, for any sampled
  fault pattern (the fabric may degrade; the math may not).
* **Recovery** — the end-to-end chaos run injects a link flap mid-train:
  the loop must roll back, quarantine, fall back along the declared
  chain, re-plan under the mask without recompiling, and probe its way
  back to the preferred fabric once the fault clears.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hyp_compat import given, settings
    from _hyp_compat import strategies as st

from repro.configs.base import ModelConfig, MoECfg
from repro.core import (
    ControllerConfig,
    FabricFaultError,
    FaultScenario,
    ScheduleRuntime,
    ScheduleTable,
    apply_link_mask,
    check_schedule_mask,
    decompose,
    decompose_batch,
    fault_hook,
    plan_schedule,
)
from repro.models import moe
from repro.parallel.fabric import (
    DEGRADATION_CHAIN,
    FABRICS,
    get_fabric,
    next_fabric,
    wrap_faulty,
)

N_V = 4


def _cfg(dispatch: str = "dense", **moe_kw):
    kw = dict(
        n_experts=8, top_k=2, d_ff_expert=32, dispatch=dispatch,
        capacity_factor=8.0,
    )
    kw.update(moe_kw)
    return ModelConfig(
        name="faults-test",
        family="moe",
        n_layers=1,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=128,
        moe=MoECfg(**kw),
        remat="none",
    )


def _traffic(seed: int, scale: float = 400.0, n: int = N_V) -> np.ndarray:
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) * scale
    np.fill_diagonal(m, 0)
    return m


def _masked_row(seed: int, mask: np.ndarray):
    plan = plan_schedule(decompose(_traffic(seed), "maxweight", link_mask=mask))
    return ScheduleTable.from_schedules([plan], k_max=N_V, envelope="auto").row(0)


def _routed_caps(sched, n: int = N_V) -> np.ndarray:
    """[n, n] per-pair capacity a schedule actually grants."""
    caps = np.zeros((n, n))
    perms = np.asarray(sched.perms)
    valid = np.asarray(sched.valid)
    cap = np.asarray(sched.caps)
    for k in range(perms.shape[0]):
        for i in range(n):
            if valid[k, i]:
                caps[i, perms[k, i]] += cap[k] if cap.ndim == 1 else cap[k, i]
    return caps


class TestFaultScenario:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultScenario("meteor_strike", n_ranks=4)

    def test_deterministic_in_seed(self):
        a = FaultScenario("dead_link", n_ranks=8, n_links=5, seed=7)
        b = FaultScenario("dead_link", n_ranks=8, n_links=5, seed=7)
        assert a.dead_pairs == b.dead_pairs
        for step in (0, 19, 20, 21, 100):
            np.testing.assert_array_equal(a.link_mask(step), b.link_mask(step))

    def test_dead_link_timeline(self):
        sc = FaultScenario("dead_link", n_ranks=4, onset=10, n_links=2, seed=1)
        assert not sc.active(9)
        assert sc.active(10) and sc.active(10_000)
        assert sc.link_mask(9).all()
        m = sc.link_mask(10)
        assert (~m).sum() == 2
        assert m.diagonal().all()
        for i, j in sc.dead_pairs:
            assert i != j and not m[i, j]

    def test_link_flap_recovers(self):
        sc = FaultScenario("link_flap", n_ranks=4, onset=5, window=3, seed=0)
        assert sc.link_mask(4).all()
        assert not sc.link_mask(5).all()
        assert not sc.link_mask(7).all()
        assert sc.link_mask(8).all()

    def test_slow_link_keeps_mask_clean(self):
        sc = FaultScenario(
            "slow_link", n_ranks=4, onset=2, window=4, slow_factor=8.0, seed=3
        )
        assert sc.link_mask(3).all()  # degraded, not dark
        slow = sc.slow_matrix(3)
        assert slow.max() == 8.0
        assert (slow >= 1.0).all()
        assert sc.slow_matrix(0).max() == 1.0
        assert sc.slow_matrix(6).max() == 1.0

    def test_dark_window_defaults(self):
        sc = FaultScenario("dark_window", n_ranks=4, dark_window_us=500.0)
        assert sc.dark_window_steps >= 1
        assert not sc.active(100)
        assert sc.link_mask(100).all()

    def test_outage_frac_overrides_n_links(self):
        sc = FaultScenario(
            "dead_link", n_ranks=8, onset=0, n_links=1, outage_frac=0.25, seed=0
        )
        assert len(sc.dead_pairs) == round(0.25 * 8 * 7)

    def test_never_kills_every_pair(self):
        sc = FaultScenario(
            "dead_link", n_ranks=2, onset=0, outage_frac=0.99, seed=0
        )
        m = sc.link_mask(0)
        assert (m & ~np.eye(2, dtype=bool)).any()


class TestApplyLinkMask:
    def test_conserves_row_demand(self):
        m = _traffic(0)
        sc = FaultScenario("dead_link", n_ranks=N_V, onset=0, n_links=3, seed=2)
        mask = sc.link_mask(0)
        out = apply_link_mask(m, mask)
        np.testing.assert_allclose(out.sum(axis=1), m.sum(axis=1))
        assert (out[~mask] == 0).all()

    def test_idempotent(self):
        m = _traffic(1)
        mask = FaultScenario(
            "dead_link", n_ranks=N_V, onset=0, n_links=4, seed=5
        ).link_mask(0)
        once = apply_link_mask(m, mask)
        np.testing.assert_allclose(apply_link_mask(once, mask), once)

    def test_unroutable_row_recorded(self):
        # row 0 loses every off-diagonal destination
        m = _traffic(2, n=3)
        mask = np.ones((3, 3), dtype=bool)
        mask[0, 1] = mask[0, 2] = False
        meta = {}
        out = apply_link_mask(m, mask, meta=meta)
        assert (out[0, 1:] == 0).all()
        np.testing.assert_allclose(meta["unroutable_tokens"], m[0, 1:].sum())

    def test_uniform_redistribution_when_survivors_idle(self):
        # all of row 0's demand targets the dead pair: survivors carried
        # nothing, so the displaced demand splits uniformly
        m = np.zeros((N_V, N_V))
        m[0, 1] = 90.0
        mask = np.ones((N_V, N_V), dtype=bool)
        mask[0, 1] = False
        out = apply_link_mask(m, mask)
        np.testing.assert_allclose(out[0], [0.0, 0.0, 45.0, 45.0])

    def test_shape_errors(self):
        with pytest.raises(ValueError, match="square demand matrix"):
            apply_link_mask(np.ones((2, 3)), np.ones((2, 3), bool))
        with pytest.raises(ValueError, match="does not match demand"):
            apply_link_mask(np.ones((3, 3)), np.ones((2, 2), bool))


class TestMaskedPlanning:
    @given(st.integers(min_value=0, max_value=10**6))
    @settings(max_examples=15, deadline=None)
    def test_masked_plan_never_routes_dark_pairs(self, seed):
        sc = FaultScenario(
            "dead_link",
            n_ranks=N_V,
            onset=0,
            n_links=1 + seed % (N_V * (N_V - 1) - 1),
            seed=seed,
        )
        mask = sc.link_mask(0)
        d = decompose(_traffic(seed), "maxweight", link_mask=mask)
        assert d.meta.get("link_masked") is True
        caps = _routed_caps(plan_schedule(d))
        assert (caps[~mask] == 0).all(), (seed, np.argwhere(~mask))
        # and check_schedule_mask agrees the plan is clean
        check_schedule_mask(plan_schedule(d), mask, backend="test")

    def test_unmasked_plan_trips_the_guard(self):
        sched = plan_schedule(decompose(_traffic(0), "maxweight"))
        caps = _routed_caps(sched)
        # pick a pair the plan actually uses and declare it dark
        i, j = map(int, np.argwhere(caps > 0)[0])
        mask = np.ones((N_V, N_V), dtype=bool)
        mask[i, j] = False
        with pytest.raises(FabricFaultError) as e:
            check_schedule_mask(
                sched, mask, backend="ragged_a2a",
                next_fabric="phase_pipelined", step=12,
            )
        err = e.value
        assert err.backend == "ragged_a2a"
        assert err.pair == (i, j)
        assert err.phase is not None and err.step == 12
        assert err.next_fabric == "phase_pipelined"
        np.testing.assert_array_equal(err.link_mask, mask)
        msg = str(err)
        assert f"link ({i} -> {j}) is dark at step 12" in msg
        assert "phase_pipelined" in msg and "degradation chain" in msg

    def test_no_fallback_message(self):
        sched = plan_schedule(decompose(_traffic(0), "maxweight"))
        i, j = map(int, np.argwhere(_routed_caps(sched) > 0)[0])
        mask = np.ones((N_V, N_V), dtype=bool)
        mask[i, j] = False
        with pytest.raises(FabricFaultError, match="no fallback fabric"):
            check_schedule_mask(sched, mask, backend="dense", next_fabric=None)

    def test_all_up_mask_is_free(self):
        sched = plan_schedule(decompose(_traffic(0), "maxweight"))
        check_schedule_mask(sched, np.ones((N_V, N_V), bool), backend="x")

    def test_decompose_batch_shares_one_mask(self):
        mask = FaultScenario(
            "dead_link", n_ranks=N_V, onset=0, n_links=3, seed=9
        ).link_mask(0)
        stack = np.stack([_traffic(s) for s in range(3)])
        decs = decompose_batch(stack, "maxweight", link_mask=mask)
        for d in decs:
            assert d.meta.get("link_masked") is True
            caps = _routed_caps(plan_schedule(d))
            assert (caps[~mask] == 0).all()

    def test_generic_strategies_masked_too(self):
        mask = FaultScenario(
            "dead_link", n_ranks=N_V, onset=0, n_links=2, seed=4
        ).link_mask(0)
        for strategy in ("bvn", "bvn-bottleneck", "shift"):
            d = decompose(_traffic(3), strategy, link_mask=mask)
            for ph in d.phases:
                perm = np.asarray(ph.perm)
                sent = np.asarray(ph.sent)
                for i in range(N_V):
                    if not mask[i, perm[i]]:
                        # BVN peeling leaves float residue on zeroed pairs
                        assert sent[i] < 1e-9, (strategy, i, int(perm[i]))


class TestChaosParity:
    """A masked plan is still a plan: values, grads, and zero drops must
    match the dense pair-caps oracle for any sampled fault pattern."""

    @given(st.integers(min_value=0, max_value=10**6))
    @settings(max_examples=4, deadline=None)
    def test_masked_row_matches_dense_oracle(self, seed):
        sc = FaultScenario(
            "link_flap",
            n_ranks=N_V,
            onset=0,
            window=1,
            n_links=1 + seed % 6,
            seed=seed,
        )
        row = _masked_row(seed, sc.link_mask(0))
        cfg = _cfg("phase_pipelined")
        params = moe.moe_init(jax.random.PRNGKey(seed % 97), cfg)
        x = jax.random.normal(
            jax.random.PRNGKey(seed % 89 + 1), (2, 16, 32), jnp.float32
        )

        y, st_f = moe.moe_apply(
            params, cfg, x, schedule=row, return_stats=True
        )
        y_ref, st_ref = moe._moe_dense(
            params, _cfg(), x, row, return_stats=True
        )
        np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)
        # the fabric degraded; no admitted token may be dropped
        assert float(np.asarray(st_f["dropped"]).sum()) == 0.0, seed
        assert float(np.asarray(st_ref["dropped"]).sum()) == 0.0
        np.testing.assert_allclose(
            np.asarray(st_f["routing"]), np.asarray(st_ref["routing"])
        )

        def loss_fab(p):
            return jnp.sum(moe.moe_apply(p, cfg, x, schedule=row) ** 2)

        def loss_ref(p):
            return jnp.sum(moe._moe_dense(p, _cfg(), x, row) ** 2)

        g_f = jax.grad(loss_fab)(params)
        g_r = jax.grad(loss_ref)(params)
        flat_f, _ = jax.tree_util.tree_flatten(g_f)
        flat_r, _ = jax.tree_util.tree_flatten(g_r)
        for a, b in zip(flat_f, flat_r):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


class TestDegradationChain:
    def test_chain_ends_at_dense(self):
        assert DEGRADATION_CHAIN[-1] == "dense"
        assert next_fabric("dense") is None

    def test_chain_walk(self):
        for a, b in zip(DEGRADATION_CHAIN, DEGRADATION_CHAIN[1:]):
            assert next_fabric(a) == b

    def test_unknown_and_wrapped_names(self):
        assert next_fabric("warp_drive") == "dense"
        assert next_fabric("faulty:ragged_a2a") == next_fabric("ragged_a2a")


class TestFaultInjectionFabric:
    def test_wrap_registers_and_mirrors_flags(self):
        sc = FaultScenario("dead_link", n_ranks=N_V, onset=0, seed=0)
        name = wrap_faulty("ragged_a2a", sc)
        try:
            fab = get_fabric(name)
            base = get_fabric("ragged_a2a")
            assert name == "faulty:ragged_a2a"
            assert fab.uses_mesh == base.uses_mesh
            assert fab.schedule_kind == base.schedule_kind
            assert fab.requires_envelope == base.requires_envelope
        finally:
            FABRICS.pop(name, None)

    def test_wrapper_refuses_dark_schedule(self):
        sched = plan_schedule(decompose(_traffic(0), "maxweight"))
        caps = _routed_caps(sched)
        i, j = map(int, np.argwhere(caps > 0)[0])
        # scenario whose sampled pair is (i, j): brute-force a seed
        seed = next(
            s for s in range(500)
            if FaultScenario(
                "dead_link", n_ranks=N_V, onset=0, n_links=1, seed=s
            ).dead_pairs == ((i, j),)
        )
        sc = FaultScenario("dead_link", n_ranks=N_V, onset=0, n_links=1, seed=seed)
        name = wrap_faulty("ppermute", sc)
        try:
            fab = get_fabric(name)
            fab.advance(5)
            with pytest.raises(FabricFaultError) as e:
                fab.check_transfers(sched)
            assert e.value.backend == "ppermute"
            assert e.value.pair == (i, j)
            assert fab.faults_raised == 1
            # before onset the same schedule passes
            fab.advance(-1)
            fab.check_transfers(sched)
            # masked plans pass during the outage
            fab.advance(5)
            masked = plan_schedule(
                decompose(_traffic(0), "maxweight", link_mask=sc.link_mask(5))
            )
            fab.check_transfers(masked)
            assert fab.validate_schedule(masked, n=N_V) is not None
        finally:
            FABRICS.pop(name, None)


class TestFaultHook:
    def _runtime(self, **kw):
        cfg = dict(
            n_ranks=N_V, n_experts=8, ema=1.0, cooldown=0,
            fallback_chain=("ragged_a2a", "dense"),
        )
        cfg.update(kw)
        rt = ScheduleRuntime(ControllerConfig(**cfg), 1)
        rt.prime(_traffic(0, scale=1000.0))
        return rt

    def test_hook_raises_then_clears(self):
        rt = self._runtime()
        caps = _routed_caps(rt.schedules[0])
        i, j = map(int, np.argwhere(caps > 0)[0])
        seed = next(
            s for s in range(500)
            if FaultScenario(
                "link_flap", n_ranks=N_V, onset=3, window=2, seed=s
            ).dead_pairs == ((i, j),)
        )
        sc = FaultScenario("link_flap", n_ranks=N_V, onset=3, window=2, seed=seed)
        hook = fault_hook(sc, rt, backend="ragged_a2a")
        hook(0)  # healthy: no-op
        assert rt.link_mask is None
        with pytest.raises(FabricFaultError) as e:
            hook(3)
        assert e.value.next_fabric == "dense"
        # the loop hands the error to the runtime: mask adopted, replanned
        rt.record_fault(e.value)
        assert rt.link_mask is not None
        assert rt.metrics()["fabric_faults"] == 1
        hook(4)  # same outage, plans now routed around it: no-op
        hook(5)  # fault cleared: mask lifted, replan back to preferred
        assert rt.link_mask is None

    def test_hook_adopts_mask_silently_when_plans_avoid_it(self):
        # traffic with NO demand on pair (0, 1): the plan never routes
        # it, so darkening it must not raise — the mask is adopted
        # silently so future re-plans keep avoiding it
        rt = ScheduleRuntime(
            ControllerConfig(
                n_ranks=N_V, n_experts=8, ema=1.0, cooldown=0,
                fallback_chain=("ragged_a2a", "dense"),
            ),
            1,
        )
        m = _traffic(0, scale=1000.0)
        m[0, 1] = 0.0
        rt.prime(m)
        caps = _routed_caps(rt.schedules[0])
        assert caps[0, 1] == 0
        seed = next(
            s for s in range(2000)
            if FaultScenario(
                "dead_link", n_ranks=N_V, onset=0, seed=s
            ).dead_pairs == ((0, 1),)
        )
        sc = FaultScenario("dead_link", n_ranks=N_V, onset=0, seed=seed)
        hook = fault_hook(sc, rt, backend="ragged_a2a")
        hook(0)  # no raise: plans never touch the dark pair
        assert rt.link_mask is not None
        assert rt.metrics()["masked_replans"] == 1


class TestChaosEndToEnd:
    def test_link_flap_training_recovers(self, tmp_path):
        """The acceptance run: a seeded link flap mid-train must (1) be
        surfaced as a ``FabricFaultError`` the loop rolls back from,
        (2) quarantine the preferred fabric and fall back along the
        declared chain, (3) re-plan under the availability mask without
        recompiling the step, and (4) probe back to the preferred fabric
        once the fault clears — finishing HEALTHY with finite losses."""
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        N, E = 4, 8
        cfg = ModelConfig(
            name="fault-e2e",
            family="moe",
            n_layers=2,
            d_model=32,
            n_heads=4,
            n_kv_heads=2,
            d_ff=64,
            vocab_size=128,
            moe=MoECfg(
                n_experts=E, top_k=2, d_ff_expert=32,
                dispatch="phase_pipelined",
            ),
            remat="none",
        )
        model = Model(cfg)
        rt = ScheduleRuntime(
            ControllerConfig(
                n_ranks=N,
                n_experts=E,
                ema=1.0,
                cooldown=2,
                envelope_slack=2.0,  # recovery re-plan must fit the envelope
                fallback_chain=("phase_pipelined", "dense"),
                quarantine_after=2,
                probe_backoff=4,
                recover_after=2,
            ),
            model.n_moe_layers,
        )
        rt.prime(np.full((N, N), 50.0))
        sc = FaultScenario(
            "link_flap", n_ranks=N, onset=8, window=6, n_links=2, seed=3
        )
        rt.attach_faults(sc)

        res = train_loop(
            model,
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8),
            TrainLoopConfig(
                steps=30,
                ckpt_dir=str(tmp_path),
                ckpt_every=4,
                peak_lr=5e-3,
                warmup=5,
                log_every=2,
            ),
            runtime=rt,
            failure_hook=fault_hook(sc, rt, backend="phase_pipelined"),
        )
        ctl = res["controller"]
        # (1) the fault fired and consumed exactly one failure budget slot
        assert res["failures"] >= 1
        assert ctl["fabric_faults"] >= 1
        # (2) quarantine + fallback: the FSM demoted, the loop rebuilt
        # the step for the fallback and again for the recovery
        assert ctl["quarantines"] >= 1
        assert ctl["fabric_switches"] >= 2
        # (3) masked re-plan happened, and every recompile is accounted
        # for by a deliberate envelope change — the fault/fallback
        # machinery itself (masked swaps, quarantine, probing) adds ZERO
        # (the controlled zero-recompile masked-swap check lives in
        # benchmarks/compile_smoke.py where traffic is held fixed)
        assert ctl["masked_replans"] >= 1
        budget = ctl["envelope_growths"] + ctl["envelope_shrinks"]
        assert ctl["compiles"] <= budget, ctl
        # (4) fully recovered: preferred fabric, no mask, HEALTHY
        assert ctl["final_dispatch"] == "phase_pipelined"
        assert not ctl["fallback_active"]
        assert not ctl["link_masked"]
        assert ctl["health_state"] == "HEALTHY"
        assert ctl["active_fabric"] == "phase_pipelined"
        losses = [h["loss"] for h in res["history"]]
        assert losses and all(np.isfinite(losses)), losses
        steps = [h["step"] for h in res["history"]]
        assert len(steps) == len(set(steps))  # rollback never double-logged
