"""Sharding-rule unit tests: logical mapping, divisibility fallback, and
the parameter spec table (single process; 1-device mesh only checks the
no-mesh no-op path, mapping logic is exercised with a fake mesh object)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.models import Model
from repro.parallel import axis_rules, logical_to_spec, shard
from repro.train import param_logical_axes, param_specs


class FakeMesh:
    """Duck-typed mesh: enough for rule resolution without devices."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        import numpy as np

        self.devices = np.empty(tuple(shape.values()), dtype=object)


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestLogicalToSpec:
    def test_basic_mapping(self):
        with axis_rules(MESH):
            spec = logical_to_spec(("batch", None, "mlp"), (256, 4096, 12800))
        assert spec == P(("pod", "data"), None, "model")

    def test_divisibility_fallback_replicates(self):
        with axis_rules(MESH):
            # 12 heads not divisible by 16-way model axis -> replicated
            spec = logical_to_spec(("batch", None, "heads", None), (256, 1, 12, 128))
        assert spec == P(("pod", "data"), None, None, None)

    def test_axis_not_reused_within_tensor(self):
        with axis_rules(MESH, {"seq_kv": ("model",)}):
            spec = logical_to_spec(
                ("seq_kv", "kv_heads", None), (32768, 16, 128)
            )
        # model consumed by seq_kv; kv_heads must not reuse it
        assert spec == P("model", None, None)

    def test_missing_mesh_axis_dropped(self):
        single = FakeMesh({"data": 16, "model": 16})
        with axis_rules(single):
            spec = logical_to_spec(("batch", None), (256, 10))
        assert spec == P("data", None)

    def test_partial_tuple_fallback(self):
        with axis_rules(MESH, {"longseq": ("data", "model")}):
            # divisible by data(16) but not by data*model(256)
            spec = logical_to_spec(("longseq",), (16 * 10,))
        assert spec == P("data")

    def test_no_mesh_noop(self):
        x = jnp.zeros((4, 8))
        assert shard(x, "batch", None) is x


class TestParamSpecs:
    def test_dense_arch_specs(self):
        cfg = smoke_config("granite-3-8b")
        params = jax.eval_shape(Model(cfg).init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        with axis_rules(MESH, {"fsdp": ("data",)}):
            specs = param_specs(params)
        # embed table [V, d]: vocab over model (if divisible), d over fsdp
        emb = specs["embed"]["table"]
        assert emb[1] in ("data", ("data",))
        # stacked attn q: [periods, d, H*hd] -> (None, fsdp, model)
        q = specs["stack"]["pos0"]["mixer"]["q"]["w"]
        assert q[0] is None

    def test_moe_expert_specs(self):
        cfg = smoke_config("qwen3-moe-235b-a22b")
        params = jax.eval_shape(Model(cfg).init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        with axis_rules(MESH):
            axes = param_logical_axes(params)
        wg = axes["stack"]["pos0"]["ffn"]["w_gate"]
        assert wg == (None, "expert", "fsdp_moe", "expert_mlp")

    def test_all_leaves_get_spec(self):
        cfg = smoke_config("jamba-1.5-large-398b")
        params = jax.eval_shape(Model(cfg).init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        with axis_rules(MESH):
            specs = param_specs(params)
        n_params = len(jax.tree.leaves(params))
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)))
        assert n_params == n_specs
