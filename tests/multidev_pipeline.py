"""Pipeline-parallel correctness: gpipe over 4 stages == sequential.

Run via tests/test_multidevice.py (8 fake devices).
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import gpipe


def stage_fn(params, x):
    """Residual MLP stage (shape-preserving)."""
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return x + h @ params["w2"]


def main() -> None:
    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "pipe"))
    p_stages, d = 4, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {
        "w1": jax.random.normal(keys[0], (p_stages, d, 32)) * 0.3,
        "b1": jax.random.normal(keys[1], (p_stages, 32)) * 0.1,
        "w2": jax.random.normal(keys[2], (p_stages, 32, d)) * 0.3,
    }
    n_micro, mb = 6, 8
    x = jax.random.normal(jax.random.PRNGKey(3), (n_micro, mb, d))

    # sequential reference: apply the 4 stages in order to each microbatch
    ref = x
    for s in range(p_stages):
        ps = jax.tree.map(lambda a: a[s], params)
        ref = jax.vmap(lambda xm: stage_fn(ps, xm))(ref)

    out = jax.jit(
        lambda p, x: gpipe(stage_fn, p, x, mesh=mesh, axis="pipe", n_micro=n_micro)
    )(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    print(f"OK gpipe({p_stages} stages, {n_micro} microbatches) == sequential")

    # bubble sanity: ticks = M + P - 1 (structural property of the schedule)
    assert n_micro + p_stages - 1 == 9
    print("ALL PIPELINE CHECKS PASSED")


if __name__ == "__main__":
    main()
