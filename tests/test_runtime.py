"""Controller-loop runtime tests (core/runtime.py): observe -> score ->
re-plan -> swap, plus the end-to-end drift training run."""

import numpy as np
import pytest

from repro.core import (
    ControllerConfig,
    DriftScenario,
    ScheduleRuntime,
    routing_to_traffic,
)

N, E, L = 4, 8, 3


def _stats(probs: np.ndarray, tokens: float = 4096.0, n_src: int = 1) -> np.ndarray:
    """Deterministic [L, n_src, E] routing counts under popularity ``probs``."""
    row = tokens / n_src * np.asarray(probs, dtype=np.float64)
    return np.broadcast_to(row, (L, n_src, E)).copy()


def _runtime(**kw) -> ScheduleRuntime:
    cfg = dict(
        n_ranks=N, n_experts=E, ema=1.0, cooldown=0, drop_tolerance=0.05
    )
    cfg.update(kw)
    return ScheduleRuntime(ControllerConfig(**cfg), L)


class TestRoutingToTraffic:
    def test_full_source_resolution(self):
        stats = np.arange(L * N * E, dtype=np.float64).reshape(L, N, E)
        t = routing_to_traffic(stats, n_ranks=N, n_experts=E)
        assert t.shape == (L, N, N)
        # expert blocks fold onto ranks contiguously
        np.testing.assert_allclose(
            t[0, 0], stats[0, 0].reshape(N, E // N).sum(axis=1)
        )

    def test_single_source_spreads_evenly(self):
        stats = np.ones((L, 1, E))
        t = routing_to_traffic(stats, n_ranks=N, n_experts=E)
        assert t.shape == (L, N, N)
        np.testing.assert_allclose(t.sum(), stats.sum())  # tokens conserved
        np.testing.assert_allclose(t[0], np.full((N, N), E / N / N))

    def test_bad_shapes_raise(self):
        with pytest.raises(ValueError):
            routing_to_traffic(np.ones((L, 1, E + 1)), n_ranks=N, n_experts=E)
        with pytest.raises(ValueError):
            routing_to_traffic(np.ones((L, 3, E)), n_ranks=N, n_experts=E)


class TestScheduleRuntime:
    def test_first_observe_plans_all_groups(self):
        rt = _runtime()
        probs = np.linspace(1, 2, E)
        d = rt.observe(_stats(probs))
        assert d.changed and d.replanned
        assert rt.decompose_calls == 1  # one batched call, all layers
        assert rt.schedules is not None and len(rt.schedules) == L
        assert rt.last_event["layers"] == L

    def test_steady_state_keeps_schedule(self):
        rt = _runtime()
        probs = np.linspace(1, 2, E)
        rt.observe(_stats(probs))
        for i in range(5):
            d = rt.observe(_stats(probs * (1 + 0.01 * i)))
            assert not d.changed and not d.replanned
        assert rt.decompose_calls == 1

    def test_one_decompose_batch_per_drift_event(self):
        rt = _runtime()
        rt.observe(_stats(np.linspace(1, 2, E)))
        rt.observe(_stats(np.linspace(2, 1, E) ** 4))  # hard drift
        assert rt.replan_events == rt.decompose_calls == 2

    def test_steady_state_replan_is_lap_free(self):
        """Same support, drifted weights: the batched re-plan must replay
        warm states for every layer — zero cold (LAP-solving) plans."""
        rt = _runtime()
        probs = np.linspace(1, 2, E)
        rt.observe(_stats(probs))
        assert rt.last_event["cold"] == L  # first plan is necessarily cold
        # skew the weights hard enough to miss, support unchanged
        d = rt.observe(_stats(probs**6))
        assert d.replanned
        assert rt.last_event["warm_hits"] == L
        assert rt.last_event["cold"] == 0

    def test_returning_regime_is_a_library_hit(self):
        rt = _runtime()
        a, b = np.linspace(1, 2, E), np.linspace(2, 1, E) ** 4
        rt.observe(_stats(a))
        rt.observe(_stats(b))
        replans = rt.replan_events
        d = rt.observe(_stats(a))  # regime A returns
        assert d.changed and not d.replanned  # swap without a re-plan
        assert rt.replan_events == replans

    def test_cooldown_suppresses_replan_storm(self):
        rt = _runtime(cooldown=10)
        a, b = np.linspace(1, 2, E), np.linspace(2, 1, E) ** 4
        rt.observe(_stats(a))
        for _ in range(5):  # drifted, but inside the cooldown window
            d = rt.observe(_stats(b))
            assert not d.replanned
        assert rt.replan_events == 1
        for _ in range(10):
            rt.observe(_stats(b))
        assert rt.replan_events == 2  # replanned once the window elapsed

    def test_replan_event_cools_down_every_group(self):
        """Staggered drift: layers crossing tolerance a step after an
        event must NOT each trigger their own re-plan — the event puts
        the whole runtime in cooldown, not just the groups that missed."""
        rt = _runtime(cooldown=3)
        a = np.linspace(1, 2, E)
        b = np.linspace(2, 1, E) ** 4
        rt.observe(_stats(a))
        for _ in range(4):  # burn the initial cooldown
            rt.observe(_stats(a))
        staggered = _stats(a)
        staggered[0] = _stats(b)[0]  # only layer 0 has drifted so far
        d = rt.observe(staggered)
        assert d.replanned and rt.replan_events == 2
        d2 = rt.observe(_stats(b))  # the other layers cross one step later
        assert not d2.replanned, "staggered miss must be absorbed by cooldown"
        assert rt.replan_events == 2

    def test_model_grouping_shares_one_schedule(self):
        rt = ScheduleRuntime(
            ControllerConfig(
                n_ranks=N, n_experts=E, ema=1.0, cooldown=0, group_by="model"
            ),
            L,
        )
        rt.observe(_stats(np.linspace(1, 2, E)))
        scheds = rt.schedules
        assert len(scheds) == L
        assert all(s is scheds[0] for s in scheds)
        # the batched call still decomposed every layer (warm states) plus
        # the group aggregate row
        assert rt.last_event["layers"] == L + 1

    def test_prime_bootstraps_schedules(self):
        rt = _runtime()
        traffic = np.full((N, N), 100.0)
        np.fill_diagonal(traffic, 0.0)
        d = rt.prime(traffic)
        assert d.changed and rt.schedules is not None
        sched = rt.schedules[0]
        assert sched.num_phases >= 1


class TestEndToEndDrift:
    def test_scheduled_dispatch_requires_priming(self, tmp_path):
        """Unprimed runtime + scheduled dispatch is a config error: it
        must fail fast, not burn the retry budget on trace failures."""
        from repro.configs.base import ModelConfig, MoECfg
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = ModelConfig(
            name="unprimed", family="moe", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
            moe=MoECfg(n_experts=E, top_k=2, d_ff_expert=32,
                       dispatch="scheduled"),
            remat="none",
        )
        model = Model(cfg)
        rt = ScheduleRuntime(
            ControllerConfig(n_ranks=N, n_experts=E), model.n_moe_layers
        )
        with pytest.raises(ValueError, match="prime"):
            train_loop(
                model,
                DataConfig(vocab_size=128, seq_len=16, global_batch=4),
                TrainLoopConfig(steps=2, ckpt_dir=str(tmp_path)),
                runtime=rt,
            )

    def test_drift_training_end_to_end(self, tmp_path):
        """Close the loop for real: train a small MoE while a routing
        regime shift is injected mid-run.  The runtime must re-plan all
        layers in single decompose_batch calls, hit the warm path at the
        steady-state re-plan (zero LAP solves), swap schedules, and the
        loss must keep decreasing across the swap."""
        from repro.configs.base import ModelConfig, MoECfg
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = ModelConfig(
            name="drift-test",
            family="moe",
            n_layers=2,
            d_model=32,
            n_heads=4,
            n_kv_heads=2,
            d_ff=64,
            vocab_size=128,
            moe=MoECfg(n_experts=E, top_k=2, d_ff_expert=32),
            remat="none",
        )
        model = Model(cfg)
        rt = ScheduleRuntime(
            ControllerConfig(n_ranks=N, n_experts=E, ema=1.0, cooldown=2),
            model.n_moe_layers,
        )
        shift_at = 12

        base = np.linspace(1.0, 2.0, E)
        base /= base.sum()

        def drift_hook(step, stats):
            """Deterministic synthetic counts: regime A, then at
            ``shift_at`` the same support with heavily skewed weights —
            the steady-state re-plan case (support unchanged)."""
            probs = base if step < shift_at else base**6 / (base**6).sum()
            totals = stats.sum(axis=(1, 2), keepdims=True)
            return np.broadcast_to(
                probs[None, None, :], stats.shape
            ) * totals

        res = train_loop(
            model,
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8),
            TrainLoopConfig(
                steps=30,
                ckpt_dir=str(tmp_path),
                ckpt_every=10,
                peak_lr=5e-3,
                warmup=5,
                log_every=2,
            ),
            runtime=rt,
            stats_hook=drift_hook,
        )
        ctl = res["controller"]
        # the shift triggered a re-plan on top of the initial plan, each
        # one batched decompose_batch call over all MoE layers
        assert ctl["replan_events"] >= 2
        assert ctl["decompose_calls"] == ctl["replan_events"]
        assert ctl["swaps"] >= 2
        # steady-state re-plan (support unchanged): warm path, no LAP
        assert rt.last_event["cold"] == 0
        assert rt.last_event["warm_hits"] == model.n_moe_layers
        # training kept improving across the swap
        losses = [h["loss"] for h in res["history"]]
        steps = [h["step"] for h in res["history"]]
        assert len(steps) == len(set(steps))
        assert all(np.isfinite(losses))
        assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.1, losses
        post_shift = [h["loss"] for h in res["history"] if h["step"] >= shift_at]
        assert post_shift[-1] < post_shift[0], post_shift

    def test_hybrid_interleave_controller_end_to_end(self, tmp_path):
        """PR 8 satellite: the controller over a heterogeneous
        jamba-style stack — mamba + one attention layer per period, MoE
        FFN on every SECOND layer only.  The controller's world is the
        MoE sublattice: its table has ``n_moe_layers`` rows (not
        ``n_layers``), observe/score/re-plan run over exactly those
        layers, and warm hits at the steady-state re-plan count MoE
        layers only.  Rides the quantized wire (``int8``) so the
        low-precision path is exercised inside a real training loop."""
        from repro.configs.base import HybridCfg, ModelConfig, MoECfg
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = ModelConfig(
            name="jamba-drift-test",
            family="hybrid",
            n_layers=4,
            d_model=32,
            n_heads=4,
            n_kv_heads=2,
            d_ff=64,
            vocab_size=128,
            hybrid=HybridCfg(
                period=4, attn_index=2, d_state=8, conv_width=2, expand=2
            ),
            moe=MoECfg(
                n_experts=E, top_k=2, d_ff_expert=32, every=2,
                wire_dtype="int8",
            ),
            remat="none",
        )
        model = Model(cfg)
        # the interleave: mamba / moe / attention / moe
        assert [cfg.ffn_kind(l) == "moe" for l in range(4)] == [
            False, True, False, True
        ]
        assert model.n_moe_layers == 2
        rt = ScheduleRuntime(
            ControllerConfig(n_ranks=N, n_experts=E, ema=1.0, cooldown=2),
            model.n_moe_layers,
        )
        shift_at = 10
        base = np.linspace(1.0, 2.0, E)
        base /= base.sum()
        seen_shapes = []

        def drift_hook(step, stats):
            # the loop hands the hook MoE-sublattice stats: one row per
            # dispatching layer, never one per stack layer
            seen_shapes.append(stats.shape[0])
            probs = base if step < shift_at else base**6 / (base**6).sum()
            totals = stats.sum(axis=(1, 2), keepdims=True)
            return np.broadcast_to(
                probs[None, None, :], stats.shape
            ) * totals

        res = train_loop(
            model,
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8),
            TrainLoopConfig(
                steps=24,
                ckpt_dir=str(tmp_path),
                ckpt_every=12,
                peak_lr=5e-3,
                warmup=5,
                log_every=2,
            ),
            runtime=rt,
            stats_hook=drift_hook,
        )
        assert set(seen_shapes) == {model.n_moe_layers}
        ctl = res["controller"]
        assert ctl["replan_events"] >= 2
        assert ctl["decompose_calls"] == ctl["replan_events"]
        # steady-state re-plan warm path sized by the MoE sublattice
        assert rt.last_event["cold"] == 0
        assert rt.last_event["warm_hits"] == model.n_moe_layers
        assert len(rt.table().caps) >= 1
        assert rt.table().num_layers == model.n_moe_layers
        losses = [h["loss"] for h in res["history"]]
        assert all(np.isfinite(losses))
        assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses


class TestPhaseClipAccounting:
    """``phase_clips`` must not drift when the selector's LRU bound
    recycles entry names (the eviction-prune satellite)."""

    def _clipped_entry(self, name: str, seed: int):
        from repro.core import ScheduleEntry, decompose, plan_schedule

        rng = np.random.default_rng(seed)
        m = rng.random((N, N)) * 400
        np.fill_diagonal(m, 0)
        sched = plan_schedule(decompose(m, "maxweight"))
        assert sched.num_phases > 1  # must exceed k_max=1 to count a clip
        return ScheduleEntry(
            name=name, reference=m, schedule=sched
        )

    def test_reused_name_recounts_after_eviction(self):
        rt = ScheduleRuntime(
            ControllerConfig(
                n_ranks=N, n_experts=E, ema=1.0, cooldown=0,
                group_by="model", k_max=1, max_library=2,
            ),
            L,
        )
        sel = rt.selectors[0]
        sel.register(self._clipped_entry("A", 0))
        rt.table()
        assert rt.phase_clips == 1
        rt.table()
        assert rt.phase_clips == 1  # cached/idempotent per entry
        # LRU-evict "A" (current is never evicted, so push two more)
        sel.register(self._clipped_entry("B", 1))
        sel.register(self._clipped_entry("C", 2))
        assert all(e.name != "A" for e in sel.library)
        rt.table()
        assert rt.phase_clips == 2  # the now-current "C" counts once
        # re-register a fresh clipped plan under the recycled name "A":
        # without eviction pruning this would be silently skipped
        sel.register(self._clipped_entry("A", 3))
        rt.table()
        assert rt.phase_clips >= 3, "recycled name must be re-counted"


class TestEnvelopePolicy:
    def test_growth_is_counted_and_monotone(self):
        rt = _runtime(envelope_slack=1.25)
        rt.prime(np.where(np.eye(N, dtype=bool), 0.0, 100.0))
        env1 = np.asarray(rt.table().envelope)
        assert rt.envelope_growths == 0
        # hard concentration: one pair carries almost everything — the
        # re-planned caps blow past 1.25x the day-one envelope
        hot = np.full(E, 1e-3)
        hot[-1] = 1.0
        rt.observe(_stats(hot / hot.sum(), tokens=64000.0))
        env2 = np.asarray(rt.table().envelope)
        assert rt.envelope_growths == 1
        assert (env2 >= env1).all() and (env2 > env1).any()
        # a mild re-plan inside the grown envelope must NOT grow again
        rt.observe(_stats(np.linspace(1, 1.2, E), tokens=1000.0))
        rt.table()
        assert rt.envelope_growths == 1
        assert rt.metrics()["envelope"] == [int(v) for v in env2]

    def test_slack_zero_disables_envelope(self):
        rt = _runtime(envelope_slack=0.0)
        rt.prime(np.full((N, N), 50.0))
        assert rt.table().envelope is None
        assert rt.metrics()["envelope"] is None


class TestHealthFSM:
    """Degraded-fabric health machine (PR 6): anomaly detection,
    quarantine/fallback along the chain, exponential-backoff probing,
    and the fault telemetry ``metrics()`` must surface."""

    def _chained(self, **kw):
        cfg = dict(
            fallback_chain=("ragged_a2a", "phase_pipelined", "dense"),
            quarantine_after=1,
            probe_backoff=2,
            recover_after=1,
        )
        cfg.update(kw)
        rt = _runtime(**cfg)
        rt.prime(np.full((N, N), 100.0))
        return rt

    def test_metrics_expose_health_telemetry(self):
        rt = _runtime()
        rt.prime(np.full((N, N), 50.0))
        m = rt.metrics()
        assert m["health_state"] == "HEALTHY"
        assert m["active_fabric"] is None  # no chain declared
        assert m["fallback_active"] is False
        assert m["quarantines"] == 0
        assert m["probe_failures"] == 0
        assert m["fabric_faults"] == 0
        assert m["masked_replans"] == 0
        assert m["dark_window_steps"] == 0
        assert m["link_masked"] is False

    def test_chain_validation(self):
        with pytest.raises(ValueError, match="repeats a fabric"):
            _runtime(fallback_chain=("dense", "dense"))
        with pytest.raises(ValueError, match="dispatch names"):
            _runtime(fallback_chain=("dense", ""))
        with pytest.raises(ValueError, match="quarantine_after"):
            _runtime(quarantine_after=0)
        with pytest.raises(ValueError, match="probe_backoff"):
            _runtime(probe_backoff=8, probe_backoff_max=4)

    def test_nonfinite_loss_walks_the_chain(self):
        rt = self._chained(quarantine_after=2)
        assert rt.active_fabric() == "ragged_a2a"
        assert rt.next_fabric() == "phase_pipelined"
        probs = np.full(E, 1.0 / E)
        rt.observe(_stats(probs), loss=1.0)
        assert rt.health_state == "HEALTHY"
        rt.observe(_stats(probs), loss=float("nan"))
        assert rt.quarantines == 0  # one anomaly < quarantine_after
        rt.observe(_stats(probs), loss=float("inf"))
        assert rt.quarantines == 1
        assert rt.health_state == "DEGRADED"
        assert rt.fallback_active
        assert rt.active_fabric() == "phase_pipelined"
        assert rt.last_fault["reason"].startswith("non-finite loss")

    def test_drop_spike_is_baseline_relative(self):
        """A steady 30% capacity-drop level (dense under an untrained
        router) is NOT an anomaly even above ``drop_spike_frac``; only a
        jump past 3x the running baseline quarantines."""
        rt = self._chained(drop_spike_frac=0.25, quarantine_after=1)
        probs = np.full(E, 1.0 / E)
        routed = float(_stats(probs).sum())
        for _ in range(6):
            rt.observe(_stats(probs), dropped=0.3 * routed, loss=1.0)
        assert rt.quarantines == 0, rt.last_fault
        # fabric degradation: the fraction spikes to ~95%
        rt.observe(_stats(probs), dropped=0.95 * routed, loss=1.0)
        assert rt.quarantines == 1
        assert "dropped-token spike" in rt.last_fault["reason"]

    def test_probe_failure_backs_off_exponentially(self):
        rt = self._chained()
        probs = np.full(E, 1.0 / E)
        nan = float("nan")
        rt.observe(_stats(probs), loss=nan)  # steps=1: quarantine
        assert rt.quarantines == 1 and rt.health_state == "DEGRADED"
        assert rt.active_fabric() == "phase_pipelined"
        rt.observe(_stats(probs), loss=1.0)  # steps=2 < probe_at=3
        assert rt.health_state == "DEGRADED"
        rt.observe(_stats(probs), loss=1.0)  # steps=3: probe starts
        assert rt.health_state == "PROBING"
        assert rt.active_fabric() == "ragged_a2a"
        rt.observe(_stats(probs), loss=nan)  # failed probe
        assert rt.probe_failures == 1
        assert rt.health_state == "DEGRADED"
        assert rt.active_fabric() == "phase_pipelined"  # back where it was
        # backoff doubled (2 -> 4): probe_at = 4 + 4 = 8
        for step in range(5, 8):
            rt.observe(_stats(probs), loss=1.0)
            assert rt.health_state == "DEGRADED", step
        rt.observe(_stats(probs), loss=1.0)  # steps=8: second probe
        assert rt.health_state == "PROBING"
        rt.observe(_stats(probs), loss=1.0)  # clean probe: recovered
        assert rt.health_state == "HEALTHY"
        assert rt.active_fabric() == "ragged_a2a"
        assert not rt.fallback_active
        assert rt.quarantines == 2  # initial + the failed probe

    def test_dark_windows_charged_per_replan(self):
        from repro.core import FaultScenario

        rt = _runtime()
        sc = FaultScenario("dark_window", n_ranks=N, dark_window_steps=3)
        rt.attach_faults(sc)
        rt.prime(np.full((N, N), 100.0))
        assert rt.dark_window_steps == 3  # priming plans once
        hot = np.full(E, 1e-3)
        hot[-1] = 1.0
        rt.observe(_stats(hot / hot.sum(), tokens=64000.0))
        assert rt.replan_events == 2
        assert rt.dark_window_steps == 6
        assert rt.metrics()["dark_window_steps"] == 6

    def test_set_link_mask_replans_and_clears(self):
        rt = _runtime()
        rt.prime(np.full((N, N), 100.0))
        replans = rt.replan_events
        mask = np.ones((N, N), dtype=bool)
        mask[0, 1] = False
        rt.set_link_mask(mask)
        assert rt.metrics()["link_masked"] is True
        assert rt.masked_replans == 1
        assert rt.replan_events == replans + 1
        # every planned schedule now gives the dark pair cap 0
        for sched in rt.schedules:
            perms = np.asarray(sched.perms)
            valid = np.asarray(sched.valid)
            for k in range(perms.shape[0]):
                if valid[k, 0]:
                    assert perms[k, 0] != 1
        # same mask again: no-op
        rt.set_link_mask(mask.copy())
        assert rt.masked_replans == 1
        assert rt.replan_events == replans + 1
        rt.set_link_mask(None)
        assert rt.metrics()["link_masked"] is False
        assert rt.replan_events == replans + 2
        rt.set_link_mask(None)  # already clear: no-op
        assert rt.replan_events == replans + 2
        with pytest.raises(ValueError, match="link_mask shape"):
            rt.set_link_mask(np.ones((N + 1, N + 1), bool))

    def test_envelope_frozen_while_masked(self):
        """A degraded fabric must never force the deliberate recompile
        mid-incident: masked re-plans clamp into the existing envelope
        instead of growing it."""
        rt = _runtime(envelope_slack=1.1)
        rt.prime(np.where(np.eye(N, dtype=bool), 0.0, 100.0))
        env = np.asarray(rt.table().envelope)
        mask = np.ones((N, N), dtype=bool)
        mask[0, 1] = False
        rt.set_link_mask(mask)
        hot = np.full(E, 1e-3)
        hot[-1] = 1.0
        rt.observe(_stats(hot / hot.sum(), tokens=64000.0))
        np.testing.assert_array_equal(np.asarray(rt.table().envelope), env)
        assert rt.envelope_growths == 0
        # mask lifted: the same hot regime may now grow it (deliberate)
        rt.set_link_mask(None)
        rt.observe(_stats(hot / hot.sum(), tokens=64000.0))
        rt.table()
        assert rt.envelope_growths >= 1

    def test_record_fault_adopts_mask_and_quarantines(self):
        from repro.core import FabricFaultError

        rt = self._chained()
        mask = np.ones((N, N), dtype=bool)
        mask[2, 1] = False
        err = FabricFaultError(
            "ragged_a2a: link (2 -> 1) is dark",
            backend="ragged_a2a",
            pair=(2, 1),
            phase=0,
            link_mask=mask,
            next_fabric="phase_pipelined",
        )
        rt.record_fault(err)
        assert rt.fabric_faults == 1
        assert rt.quarantines == 1
        assert rt.health_state == "DEGRADED"
        assert rt.active_fabric() == "phase_pipelined"
        assert rt.link_mask is not None and not rt.link_mask[2, 1]
