"""Per-architecture smoke tests: reduced config, one forward + train-grad
step + decode step on CPU; asserts shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, smoke_config
from repro.models import Model

BATCH, SEQ = 2, 16


def _batch(cfg, key):
    kt, ke = jax.random.split(key)
    s_tok = SEQ - cfg.frontend_tokens
    tokens = jax.random.randint(kt, (BATCH, s_tok), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "targets": jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1),
    }
    if cfg.frontend != "none":
        batch["ext_embeds"] = (
            jax.random.normal(ke, (BATCH, cfg.frontend_tokens, cfg.d_model)) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits = jax.jit(model.forward)(
        params, batch["tokens"], batch.get("ext_embeds")
    )
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{arch}: bad grads"
    # a model this size should have nontrivial gradient signal
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in flat) ** 0.5
    assert gnorm > 0.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_prefill_decode(arch):
    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    max_len = SEQ + 4

    caches = model.init_cache(BATCH, max_len)
    logits, caches = jax.jit(model.prefill)(
        params, batch["tokens"], caches, batch.get("ext_embeds")
    )
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    token = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab_size
    step_fn = jax.jit(model.decode_step)
    for i in range(2):
        logits, caches = step_fn(params, token, caches, jnp.int32(SEQ + i))
        assert logits.shape == (BATCH, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: decode step {i}"
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize(
    "arch",
    ["granite-3-8b", "rwkv6-7b", "jamba-1.5-large-398b", "musicgen-large"],
)
def test_decode_matches_forward(arch, monkeypatch):
    """Teacher-forced decode logits must match the parallel forward —
    the strongest correctness check for caches/states.  Run in f32 with
    f32 caches so any mismatch is a logic bug, not bf16 rounding."""
    import repro.models.layers as layers

    monkeypatch.setattr(layers, "COMPUTE_DTYPE", jnp.float32)
    cfg = smoke_config(arch)
    if cfg.frontend != "none":
        cfg = __import__("dataclasses").replace(cfg, frontend="none", frontend_tokens=0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)

    full = model.forward(params, tokens)  # [1, 8, V]

    caches = model.init_cache(1, 16, dtype=jnp.float32)
    _, caches = model.prefill(params, tokens[:, :4], caches)
    step_fn = jax.jit(model.decode_step)
    outs = []
    for i in range(4, 8):
        logits, caches = step_fn(params, tokens[:, i], caches, jnp.int32(i))
        outs.append(logits)
    # logits at position i (given tokens <= i) must match forward's row i
    for j, i in enumerate(range(4, 8)):
        np.testing.assert_allclose(
            np.asarray(outs[j][0]),
            np.asarray(full[0, i]),
            rtol=1e-4,
            atol=1e-4,
        )


def test_full_configs_param_counts():
    """Sanity: full configs roughly match their advertised sizes."""
    expect = {
        "rwkv6-7b": (6e9, 9e9),
        "granite-34b": (30e9, 36e9),
        "dbrx-132b": (115e9, 145e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "jamba-1.5-large-398b": (330e9, 420e9),
        "qwen2-1.5b": (1.2e9, 2.1e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.1f}B params outside [{lo/1e9},{hi/1e9}]"
