"""Multi-device training-loop checks (run via tests/test_multidevice.py):

1. distributed MoE training runs under a (data=4, model=2) mesh with
   sharded params/optimizer + batch sharding,
2. fault tolerance: an injected failure rolls back to the last checkpoint
   and the final state matches the failure-free run exactly
   (deterministic data replay),
3. elastic restart: the same checkpoint restores onto a different mesh
   layout (data=2, model=4) and training continues.
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import smoke_config
from repro.data import DataConfig
from repro.models import Model
from repro.parallel import axis_rules
from repro.train import TrainLoopConfig, train_loop

CKPT = "/tmp/repro_multidev_ckpt"


def make_model():
    cfg = smoke_config("qwen3-moe-235b-a22b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="a2a")
    )
    return cfg, Model(cfg)


def batch_sharder(mesh):
    def shard_batch(b):
        out = {}
        for k, v in b.items():
            spec = P("data", *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, NamedSharding(mesh, spec))
        return out

    return shard_batch


def run(mesh_shape, steps, failure_hook=None, ckpt_every=5):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    cfg, model = make_model()
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    loop_cfg = TrainLoopConfig(
        steps=steps,
        ckpt_dir=CKPT,
        ckpt_every=ckpt_every,
        microbatches=2,
        peak_lr=1e-3,
        warmup=4,
        log_every=1,
    )
    with axis_rules(mesh):
        return train_loop(
            model,
            data_cfg,
            loop_cfg,
            shard_batch=batch_sharder(mesh),
            failure_hook=failure_hook,
        )


def main() -> None:
    assert jax.device_count() == 8

    # --- clean run -----------------------------------------------------
    shutil.rmtree(CKPT, ignore_errors=True)
    res_clean = run((4, 2), steps=12)
    assert res_clean["final_step"] == 12
    losses = [h["loss"] for h in res_clean["history"]]
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses
    clean_final = res_clean["final_loss"]
    print(f"OK clean run: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # --- fault tolerance: inject a failure at step 8, first attempt -----
    shutil.rmtree(CKPT, ignore_errors=True)
    state = {"fired": False}

    def boom(step):
        if step == 8 and not state["fired"]:
            state["fired"] = True
            raise RuntimeError("injected node failure")

    res_ft = run((4, 2), steps=12, failure_hook=boom)
    assert state["fired"]
    assert res_ft["failures"] == 1
    assert res_ft["final_step"] == 12
    # deterministic replay: identical final loss despite the crash
    np.testing.assert_allclose(res_ft["final_loss"], clean_final, rtol=1e-5)
    print(f"OK fault-tolerant run matches clean final loss {clean_final:.4f}")

    # --- elastic restart on a different mesh ----------------------------
    # keep the checkpoints from the ft run (latest = step 12 ckpt at 10);
    # continue to 15 steps on a (2, 4) mesh.
    res_el = run((2, 4), steps=15)
    assert res_el["final_step"] == 15
    assert np.isfinite(res_el["final_loss"])
    print(f"OK elastic restart on (2,4) mesh: final loss {res_el['final_loss']:.4f}")

    # --- controller loop over SCHEDULED dispatch ------------------------
    # Close the loop on a real EP mesh: the runtime primes the schedule,
    # drift injected into the observed routing forces a re-plan, and the
    # re-planned ScheduleTable swaps into the SAME executable — the whole
    # run must perform ZERO schedule-driven recompiles.
    from repro.core import ControllerConfig, DriftScenario, ScheduleRuntime

    shutil.rmtree(CKPT, ignore_errors=True)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg, _ = make_model()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="scheduled")
    )
    model = Model(cfg)
    n_ep = 2  # model-axis size
    runtime = ScheduleRuntime(
        ControllerConfig(
            n_ranks=n_ep,
            n_experts=cfg.moe.n_experts,
            ema=1.0,
            cooldown=2,
            group_by="model",
        ),
        model.n_moe_layers,
    )
    tokens = 8 * 16 * cfg.moe.top_k
    runtime.prime(np.full((n_ep, n_ep), tokens / n_ep**2))
    scenario = DriftScenario(
        "shift", cfg.moe.n_experts, shift_step=6, seed=0
    )
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    loop_cfg = TrainLoopConfig(
        steps=12, ckpt_dir=CKPT, ckpt_every=5, peak_lr=1e-3, warmup=4,
        log_every=2,
    )
    with axis_rules(mesh):
        res_ctl = train_loop(
            model,
            data_cfg,
            loop_cfg,
            shard_batch=batch_sharder(mesh),
            runtime=runtime,
            stats_hook=scenario.stats_hook,
        )
    ctl = res_ctl["controller"]
    assert res_ctl["final_step"] == 12
    assert np.isfinite(res_ctl["final_loss"])
    assert ctl["replan_events"] >= 1
    assert ctl["decompose_calls"] == ctl["replan_events"]
    assert ctl["swaps"] >= 1
    # traced tables: swaps never compile — the ONE permitted exception is
    # an accounted phase-envelope growth (the shift concentrates traffic
    # past the day-one envelope's slack here, so expect exactly that)
    assert ctl["compiles"] == ctl["envelope_growths"], ctl
    assert ctl["envelope_growths"] <= 1, ctl
    print(
        f"OK controller over scheduled dispatch: {ctl['replan_events']} "
        f"re-plans, {ctl['swaps']} swaps, {ctl['compiles']} recompiles "
        f"(= {ctl['envelope_growths']} envelope growths), "
        f"final loss {res_ctl['final_loss']:.4f}"
    )

    print("ALL TRAIN CHECKS PASSED")


if __name__ == "__main__":
    main()
