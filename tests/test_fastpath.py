"""Parity tests for the scheduler fast paths: the vectorized
implementations must reproduce the seed reference implementations —
bit-identically where the seed semantics are exact (max-weight phases,
selector scoring, schedule planning), to tight tolerance where only the
float reassociation differs (simulator closed forms, BvN delivery)."""

import numpy as np
import pytest

from repro.core import (
    CommModel,
    decompose,
    decompose_batch,
    knee_model,
    plan_schedule,
    simulate_decomposition,
)
from repro.core.maxweight import (
    maxweight_decompose,
    maxweight_decompose_batch,
    maxweight_decompose_reference,
    warm_state_of,
)
from repro.core.schedule import plan_schedule_bvn
from repro.core.selector import ScheduleEntry, ScheduleSelector
from repro.core.types import StackedPhases

COMM = CommModel(tokens_per_us=100.0, reconf_us=0.01)
KNEE = knee_model()


def _skewed(rng, n=16, scale=4000, density=0.7):
    m = np.floor(rng.random((n, n)) ** 3 * scale)
    m *= rng.random((n, n)) < density
    np.fill_diagonal(m, 0.0)
    return m


def _assert_same_phases(a, b):
    assert a.num_phases == b.num_phases
    for pa, pb in zip(a.phases, b.phases):
        assert np.array_equal(pa.perm, pb.perm)
        assert np.array_equal(pa.sent, pb.sent)
        assert np.array_equal(pa.alloc, pb.alloc)


# ------------------------------------------------------------- decomposition
class TestMaxweightParity:
    @pytest.mark.parametrize("seed", range(8))
    def test_cold_bit_identical(self, seed):
        m = _skewed(np.random.default_rng(seed))
        _assert_same_phases(
            maxweight_decompose(m), maxweight_decompose_reference(m)
        )

    @pytest.mark.parametrize("min_fill", [0.0, 0.1, 0.3])
    def test_cold_bit_identical_min_fill(self, min_fill):
        m = _skewed(np.random.default_rng(42))
        _assert_same_phases(
            maxweight_decompose(m, min_fill=min_fill),
            maxweight_decompose_reference(m, min_fill=min_fill),
        )

    def test_cold_bit_identical_max_matchings(self):
        m = _skewed(np.random.default_rng(7), density=1.0)
        _assert_same_phases(
            maxweight_decompose(m, max_matchings=4),
            maxweight_decompose_reference(m, max_matchings=4),
        )

    def test_batch_matches_per_matrix(self):
        rng = np.random.default_rng(3)
        mats = np.stack([_skewed(rng) for _ in range(6)])
        batch = maxweight_decompose_batch(mats)
        for i, d in enumerate(batch):
            _assert_same_phases(d, maxweight_decompose_reference(mats[i]))

    @pytest.mark.parametrize("min_fill", [0.0, 0.1])
    def test_warm_identical_matrix_is_bit_identical(self, min_fill):
        m = _skewed(np.random.default_rng(5), n=24)
        cold = maxweight_decompose(m, min_fill=min_fill)
        warm = maxweight_decompose(
            m, min_fill=min_fill, warm_start=warm_state_of(cold)
        )
        assert warm.meta["warm_hit"]
        _assert_same_phases(warm, cold)

    def test_warm_engages_with_max_matchings(self):
        m = _skewed(np.random.default_rng(13), n=12, density=1.0)
        cold = maxweight_decompose(m, max_matchings=3, min_fill=0.3)
        warm = maxweight_decompose(
            m, max_matchings=3, min_fill=0.3, warm_start=warm_state_of(cold)
        )
        assert warm.meta["warm_hit"]
        _assert_same_phases(warm, cold)
        # mismatched planning options must NOT take the warm path
        stale = maxweight_decompose(m, max_matchings=4, warm_start=warm_state_of(cold))
        assert not stale.meta["warm_hit"]

    def test_warm_drift_delivers_all_demand(self):
        rng = np.random.default_rng(6)
        m = _skewed(rng, n=24)
        cold = maxweight_decompose(m)
        drift = m * (1 + 0.05 * rng.random(m.shape))
        drift *= m > 0  # same support
        warm = maxweight_decompose(drift, warm_start=warm_state_of(cold))
        assert warm.meta["warm_hit"]
        warm.verify()

    def test_warm_support_change_falls_back_cold(self):
        rng = np.random.default_rng(8)
        m = _skewed(rng, n=12)
        cold = maxweight_decompose(m)
        changed = m.copy()
        changed[0, 1] = 0.0 if changed[0, 1] > 0 else 123.0
        warm = maxweight_decompose(changed, warm_start=warm_state_of(cold))
        assert not warm.meta["warm_hit"]
        _assert_same_phases(warm, maxweight_decompose_reference(changed))

    def test_warm_schedule_plans_identically_on_unchanged_traffic(self):
        m = _skewed(np.random.default_rng(9), n=24)
        cold = maxweight_decompose(m)
        warm = maxweight_decompose(m, warm_start=warm_state_of(cold))
        sc, sw = plan_schedule(cold), plan_schedule(warm)
        assert np.array_equal(sc.perms, sw.perms)
        assert np.array_equal(sc.caps, sw.caps)
        assert np.array_equal(sc.valid, sw.valid)


class TestDecomposeBatch:
    @pytest.mark.parametrize("strategy", ["maxweight", "shift", "bvn"])
    def test_matches_single(self, strategy):
        rng = np.random.default_rng(11)
        mats = np.stack([_skewed(rng, n=8) for _ in range(4)])
        np.einsum("lii->li", mats)[:] = 17.0  # local traffic present
        batch = decompose_batch(mats, strategy)
        for i, d in enumerate(batch):
            single = decompose(mats[i], strategy)
            np.testing.assert_allclose(
                d.sent_total(), single.sent_total(), atol=1e-9
            )
            np.testing.assert_array_equal(
                d.meta["local_tokens"], single.meta["local_tokens"]
            )

    def test_batch_input_unmutated(self):
        rng = np.random.default_rng(12)
        mats = np.stack([_skewed(rng, n=8) for _ in range(3)])
        np.einsum("lii->li", mats)[:] = 5.0
        before = mats.copy()
        decompose_batch(mats, "maxweight")
        np.testing.assert_array_equal(mats, before)


# ------------------------------------------------------------------ planning
def _plan_schedule_reference(decomp, *, quantum=8, slack=1.0, min_cap=8,
                             cap_quantile=None):
    """Seed plan_schedule loop (kept in-test as the parity oracle)."""
    from repro.core.schedule import A2ASchedule

    perms, caps, valid = [], [], []
    for p in decomp.phases:
        v = (p.sent > 0) & (p.perm != np.arange(decomp.n))
        if not v.any():
            continue
        vols = p.alloc[v]
        base = (
            float(np.quantile(vols, cap_quantile))
            if cap_quantile
            else float(vols.max())
        )
        cap = int(-(-max(int(np.ceil(base * slack)), min_cap) // quantum) * quantum)
        perms.append(p.perm.astype(np.int32))
        caps.append(cap)
        valid.append(v)
    if not perms:
        n = decomp.n
        return A2ASchedule(
            perms=np.arange(n, dtype=np.int32)[None, :],
            caps=np.array([max(min_cap, quantum)], dtype=np.int32),
            valid=np.zeros((1, n), dtype=bool),
        )
    return A2ASchedule(
        perms=np.stack(perms),
        caps=np.array(caps, dtype=np.int32),
        valid=np.stack(valid),
    )


class TestPlanParity:
    @pytest.mark.parametrize("kwargs", [
        {},
        {"slack": 1.1},
        {"cap_quantile": 0.9},
        {"quantum": 16, "min_cap": 32},
    ])
    def test_plan_schedule_bit_identical(self, kwargs):
        for seed in range(4):
            m = _skewed(np.random.default_rng(seed))
            d = decompose(m, "maxweight")
            fast = plan_schedule(d, **kwargs)
            ref = _plan_schedule_reference(d, **kwargs)
            assert np.array_equal(fast.perms, ref.perms)
            assert np.array_equal(fast.caps, ref.caps)
            assert np.array_equal(fast.valid, ref.valid)

    def test_plan_schedule_degenerate_all_local(self):
        d = decompose(np.diag(np.full(8, 50.0)), "maxweight")
        s = plan_schedule(d)
        assert s.num_phases == 1 and not s.valid.any()

    def test_plan_schedule_bvn_offsets_tile_disjoint(self):
        m = _skewed(np.random.default_rng(2), n=8)
        d = decompose(m, "bvn")
        s = plan_schedule_bvn(d)
        s.validate()  # offsets cumulative check is part of validate
        assert s.multi_phase


# ------------------------------------------------------------------ selector
class TestSelectorParity:
    def _entry(self, seed, n=16):
        m = _skewed(np.random.default_rng(seed), n=n)
        d = decompose(m, "maxweight", min_fill=0.1)
        return ScheduleEntry(
            name=f"e{seed}", reference=m, schedule=plan_schedule(d, slack=1.1)
        )

    @pytest.mark.parametrize("seed", range(6))
    def test_drop_fraction_bit_identical(self, seed):
        e = self._entry(seed)
        rng = np.random.default_rng(100 + seed)
        for _ in range(5):
            obs = _skewed(rng)
            assert e.drop_fraction(obs) == e.drop_fraction_reference(obs)

    def test_drop_fraction_multi_phase_close(self):
        m = _skewed(np.random.default_rng(1), n=8)
        d = decompose(m, "bvn")
        s = plan_schedule_bvn(d)
        e = ScheduleEntry(name="bvn", reference=m, schedule=s)
        obs = _skewed(np.random.default_rng(2), n=8)
        assert e.drop_fraction(obs) == pytest.approx(
            e.drop_fraction_reference(obs), abs=1e-9
        )

    def test_library_scoring_matches_per_entry(self):
        sel = ScheduleSelector(16)
        sel.library = [self._entry(s) for s in range(5)]
        obs = _skewed(np.random.default_rng(50))
        off = obs.copy()
        np.fill_diagonal(off, 0.0)
        scores = sel._score_library(off)
        for e, s in zip(sel.library, scores):
            assert s == e.drop_fraction(obs)

    def test_lru_bound_evicts_oldest(self):
        sel = ScheduleSelector(8, ema=1.0, max_library=3)
        rng = np.random.default_rng(0)
        base = _skewed(rng, n=8, density=1.0)
        for k in range(5):  # orthogonal regimes force replans
            m = np.roll(base, k, axis=1).copy()
            np.fill_diagonal(m, 0.0)
            sel.observe(m)
        assert len(sel.library) <= 3
        assert sel.evictions >= 1
        assert sel.current in sel.library

    def test_max_library_floored_at_two(self):
        sel = ScheduleSelector(8, ema=1.0, max_library=1, drop_tolerance=0.0)
        rng = np.random.default_rng(1)
        base = _skewed(rng, n=8, density=1.0)
        for k in range(5):
            m = np.roll(base, k, axis=1).copy()
            np.fill_diagonal(m, 0.0)
            sel.observe(m)
        assert len(sel.library) <= 2  # bound floored at 2, never exceeded

    def test_steady_state_returns_current_unchanged(self):
        sel = ScheduleSelector(16, ema=1.0)
        m = _skewed(np.random.default_rng(3), density=1.0)
        sel.observe(m)
        for _ in range(4):
            entry, changed = sel.observe(m * 1.01)
            assert not changed


# ----------------------------------------------------------------- simulator
def _simulate_reference(decomp, compute, comm, *, overlap=True, fabric="dual",
                        local_tokens=None):
    """Seed simulator (per-phase Python loops), as the parity oracle.
    Returns the makespan only."""
    phases = decomp.phases
    n = decomp.n
    k_total = len(phases)
    local = np.zeros(n) if local_tokens is None else np.asarray(local_tokens)
    if k_total == 0:
        return float(np.max(compute(local))) if local.any() else 0.0
    disp_dur = np.array(
        [comm.reconf_us + comm.comm_us(p.duration_tokens) for p in phases]
    )
    comb_dur = disp_dur.copy()
    recv = np.stack([p.recv_tokens() for p in phases])
    if fabric == "dual":
        disp_done = np.cumsum(disp_dur)
    else:
        disp_done = np.zeros(k_total)
    compute_done = np.zeros(k_total)
    if overlap and fabric == "dual":
        free = compute(local)
        for k in range(k_total):
            start = np.maximum(disp_done[k], free)
            free = start + compute(recv[k])
            compute_done[k] = free.max()
    if fabric == "dual":
        if not overlap:
            total_comp = compute(recv.sum(axis=0) + local)
            compute_done[:] = disp_done[-1] + total_comp.max()
        comb_free = 0.0
        for k in range(k_total):
            start = max(compute_done[k], comb_free)
            comb_free = start + comb_dur[k]
        return float(comb_free)
    net_free = 0.0
    free = compute(local)
    for k in range(k_total):
        net_free += disp_dur[k]
        disp_done[k] = net_free
        if overlap:
            start = np.maximum(disp_done[k], free)
            free = start + compute(recv[k])
            compute_done[k] = free.max()
    if not overlap:
        total_comp = compute(recv.sum(axis=0) + local)
        compute_done[:] = disp_done[-1] + total_comp.max()
    for k in range(k_total):
        start = max(compute_done[k], net_free)
        net_free = start + comb_dur[k]
    return float(net_free)


class TestSimulatorParity:
    @pytest.mark.parametrize("fabric", ["dual", "single"])
    @pytest.mark.parametrize("overlap", [True, False])
    @pytest.mark.parametrize("strategy", ["maxweight", "bvn", "shift"])
    def test_makespan_matches_reference(self, fabric, overlap, strategy):
        rng = np.random.default_rng(4)
        for _ in range(3):
            m = _skewed(rng, n=8)
            d = decompose(m, strategy)
            local = rng.random(8) * 100
            fast = simulate_decomposition(
                d, KNEE, COMM, overlap=overlap, fabric=fabric,
                local_tokens=local,
            )
            ref = _simulate_reference(
                d, KNEE, COMM, overlap=overlap, fabric=fabric,
                local_tokens=local,
            )
            assert fast.makespan_us == pytest.approx(ref, rel=1e-12)


# -------------------------------------------------------------- stacked view
class TestStackedPhases:
    def test_roundtrip(self):
        m = _skewed(np.random.default_rng(5))
        d = decompose(m, "maxweight")
        st = d.stacked()
        rebuilt = StackedPhases.from_phases(st.to_phases(), d.n)
        assert np.array_equal(rebuilt.perms, st.perms)
        assert np.array_equal(rebuilt.sent, st.sent)

    def test_recv_tokens_matches_per_phase(self):
        m = _skewed(np.random.default_rng(6))
        d = decompose(m, "maxweight")
        st = d.stacked()
        recv = st.recv_tokens()
        for k, p in enumerate(d.phases):
            np.testing.assert_array_equal(recv[k], p.recv_tokens())


# ------------------------------------------------------------------- kernels
class TestPallasExpertFFN:
    def test_moe_gemm_autotuned_matches_oracle_1e4(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels.moe_gemm import moe_gemm, moe_gemm_ref

        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        e, c, d, f = 2, 128, 64, 128  # autotune-table shape
        x = jax.random.normal(ks[0], (e, c, d), jnp.float32) * 0.5
        wg = jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.05
        wu = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.05
        wd = jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.05
        out = moe_gemm(x, wg, wu, wd)  # blocks from the autotune table
        ref = moe_gemm_ref(x, wg, wu, wd)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4
        )

    def test_untileable_shape_falls_back_to_oracle(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels.moe_gemm import moe_gemm, moe_gemm_ref

        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        # no MXU-aligned block divides 72, so compiled mode must fall back
        # to the einsum oracle (bit-identical — it IS the oracle)
        e, c, d, f = 2, 72, 16, 72
        x = jax.random.normal(ks[0], (e, c, d), jnp.float32)
        wg = jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.1
        wu = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1
        wd = jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.1
        out = moe_gemm(x, wg, wu, wd, interpret=False)
        ref = moe_gemm_ref(x, wg, wu, wd)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_odd_shape_still_tiles_in_interpret_mode(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels.moe_gemm import moe_gemm, moe_gemm_ref

        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        e, c, d, f = 2, 9, 16, 24  # interpret mode accepts full-dim blocks
        x = jax.random.normal(ks[0], (e, c, d), jnp.float32)
        wg = jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.1
        wu = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1
        wd = jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.1
        out = moe_gemm(x, wg, wu, wd)
        ref = moe_gemm_ref(x, wg, wu, wd)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5
        )

    def test_moe_apply_use_pallas_matches_einsum(self):
        import dataclasses

        import jax
        import jax.numpy as jnp

        from repro.configs.base import ModelConfig, MoECfg
        from repro.models.moe import moe_apply, moe_init

        cfg = ModelConfig(
            name="t-pallas", family="moe", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, d_ff=128, vocab_size=256,
            moe=MoECfg(
                n_experts=4, top_k=2, d_ff_expert=128, use_pallas=True
            ),
        )
        params = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 64), jnp.float32)
        y_pallas = moe_apply(params, cfg, x)
        cfg_ein = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, use_pallas=False)
        )
        y_einsum = moe_apply(params, cfg_ein, x)
        np.testing.assert_allclose(
            np.asarray(y_pallas), np.asarray(y_einsum), rtol=1e-4, atol=1e-4
        )

    def test_moe_gemm_kernel_path_is_differentiable(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels.moe_gemm import moe_gemm, moe_gemm_ref

        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        e, c, d, f = 2, 16, 8, 16  # small, takes the kernel path (interpret)
        x = jax.random.normal(ks[0], (e, c, d), jnp.float32) * 0.5
        wg = jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.1
        wu = jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1
        wd = jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.1
        g_kernel = jax.grad(lambda *a: moe_gemm(*a).sum(), argnums=(0, 1, 2, 3))(
            x, wg, wu, wd
        )
        g_ref = jax.grad(
            lambda *a: moe_gemm_ref(*a).sum(), argnums=(0, 1, 2, 3)
        )(x, wg, wu, wd)
        for gk, gr in zip(g_kernel, g_ref):
            np.testing.assert_allclose(
                np.asarray(gk), np.asarray(gr), rtol=1e-4, atol=1e-5
            )

    def test_block_selector_respects_divisibility(self):
        from repro.kernels.moe_gemm.ops import select_block_sizes

        for c, d, f in [(512, 4096, 14336), (256, 128, 256), (384, 128, 384)]:
            picked = select_block_sizes(c, d, f, interpret=True)
            assert picked is not None
            bc, bf = picked
            assert c % bc == 0 and f % bf == 0
        # compiled mode demands MXU-aligned blocks
        assert select_block_sizes(72, 64, 72, interpret=False) is None
