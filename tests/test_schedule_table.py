"""Array-native schedule tests: the traced ``ScheduleTable`` (PR 3).

Covers the tentpole properties end to end:
  * table construction/padding/clipping and the traced ``pair_caps``
    admission matrix vs the host-side ``A2ASchedule.cap_matrix`` oracle,
  * scan-vs-unrolled numerics parity on the seed MoE configs (per-layer
    tables riding ``lax.scan``),
  * prefill/decode parity with the training stack under *distinct*
    per-layer schedules,
  * the zero-recompile regression: a drift-event schedule swap must not
    grow any executable cache,
  * virtual-fabric admission semantics (scheduled capacity clipping
    observable on a single device).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.configs.base import ModelConfig, MoECfg
from repro.core import (
    A2ASchedule,
    ControllerConfig,
    ScheduleRuntime,
    ScheduleTable,
    decompose,
    plan_schedule,
)
from repro.models import Model, moe, stack

N_V = 4  # virtual fabric ranks


def _plans(n_layers: int, seed: int = 0, scale: float = 500.0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_layers):
        m = rng.random((N_V, N_V)) * scale
        np.fill_diagonal(m, 0)
        out.append(plan_schedule(decompose(m, "maxweight")))
    return out


def _moe_cfg(n_layers: int = 3, dispatch: str = "scheduled", **moe_kw):
    return ModelConfig(
        name="tbl-test",
        family="moe",
        n_layers=n_layers,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=128,
        moe=MoECfg(
            n_experts=8, top_k=2, d_ff_expert=32, dispatch=dispatch, **moe_kw
        ),
        remat="none",
    )


class TestScheduleTable:
    def test_roundtrip_and_padding(self):
        scheds = _plans(3)
        t = ScheduleTable.from_schedules(scheds, k_max=N_V)
        assert (t.num_layers, t.k_max, t.n) == (3, N_V, N_V)
        for l, s in enumerate(scheds):
            k = s.num_phases
            assert int(t.n_phases[l]) == k
            np.testing.assert_array_equal(np.asarray(t.perms[l, :k]), s.perms)
            np.testing.assert_array_equal(np.asarray(t.caps[l, :k]), s.caps)
            np.testing.assert_array_equal(np.asarray(t.valid[l, :k]), s.valid)
            # padding: invalid everywhere, zero caps
            assert not np.asarray(t.valid[l, k:]).any()
            assert not np.asarray(t.caps[l, k:]).any()

    def test_clip_raises_without_flag(self):
        scheds = _plans(2)
        k = max(s.num_phases for s in scheds)
        assert k > 1
        with pytest.raises(ValueError, match="clip"):
            ScheduleTable.from_schedules(scheds, k_max=1)
        t = ScheduleTable.from_schedules(scheds, k_max=1, clip=True)
        assert t.k_max == 1 and int(t.n_phases.max()) == 1

    def test_update_preserves_shapes_and_checks_layers(self):
        t = ScheduleTable.from_schedules(_plans(3), k_max=N_V)
        t2 = t.update(_plans(3, seed=1))
        assert all(
            a.shape == b.shape
            for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2))
        )
        with pytest.raises(ValueError, match="layers"):
            t.update(_plans(2))

    def test_pair_caps_matches_host_oracle(self):
        for e_local in (1, 2):
            for s in _plans(3, seed=2):
                row = ScheduleTable.from_schedules([s]).row(0)
                got = np.asarray(row.pair_caps(e_local))
                per_expert = -(-s.caps.astype(np.int64) // e_local)
                per_expert = np.maximum(8, -(-per_expert // 8) * 8)
                want = s.cap_matrix(caps=per_expert)
                np.testing.assert_array_equal(got, want)

    def test_row_slicing_traced(self):
        t = ScheduleTable.from_schedules(_plans(3))
        f = jax.jit(lambda tbl, l: tbl.row(l).caps)
        np.testing.assert_array_equal(
            np.asarray(f(t, jnp.int32(2))), np.asarray(t.caps[2])
        )

    def test_static_sequences_rejected(self):
        scheds = _plans(2)
        with pytest.raises(TypeError, match="ScheduleTable"):
            Model(_moe_cfg(), tuple(scheds))
        cfg = _moe_cfg(n_layers=2)
        with pytest.raises(TypeError, match="ScheduleTable"):
            stack.stack_train({}, cfg, jnp.zeros((1, 4, 32)), list(scheds))

    def test_moe_apply_rejects_full_table(self):
        cfg = _moe_cfg()
        t = ScheduleTable.from_schedules(_plans(3))
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="row"):
            moe.moe_apply(params, cfg, jnp.zeros((1, 4, 32)), schedule=t)


class TestScanUnrollParity:
    """Per-layer tables through ``lax.scan`` == the unrolled oracle, on
    the seed MoE configs (distinct plans per layer)."""

    @pytest.mark.parametrize(
        "arch", ["mixtral-8x7b", "qwen3-moe-235b-a22b"]
    )
    def test_seed_config_parity(self, arch):
        cfg = smoke_config(arch)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="scheduled")
        )
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        table = ScheduleTable.from_schedules(
            _plans(model.n_moe_layers, scale=50.0), k_max=N_V, clip=True
        )
        x = jax.random.normal(
            jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32
        )
        y_scan, st_scan = stack.stack_train(
            params["stack"], cfg, x, table, collect_stats=True
        )
        y_unroll, st_unroll = stack.stack_train(
            params["stack"], cfg, x, table, collect_stats=True, unroll=True
        )
        np.testing.assert_allclose(
            np.asarray(y_scan), np.asarray(y_unroll), atol=1e-5, rtol=1e-5
        )
        for a, b in zip(jax.tree.leaves(st_scan), jax.tree.leaves(st_unroll)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_tight_caps_still_match(self):
        """Parity must hold when the plan actually clips tokens (the
        admission mask is layer-dependent data riding the scan)."""
        cfg = _moe_cfg(n_layers=4)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        table = ScheduleTable.from_schedules(
            [
                plan_schedule(
                    decompose(m, "maxweight"), min_cap=1, quantum=1
                )
                for m in (
                    np.where(np.eye(N_V, dtype=bool), 0, r)
                    for r in np.random.default_rng(3).random((4, N_V, N_V))
                )
            ],
            k_max=N_V,
            clip=True,
        )
        x = jax.random.normal(
            jax.random.PRNGKey(2), (4, 64, cfg.d_model), jnp.float32
        )
        y_scan = stack.stack_train(params["stack"], cfg, x, table)
        y_unroll = stack.stack_train(
            params["stack"], cfg, x, table, unroll=True
        )
        np.testing.assert_allclose(
            np.asarray(y_scan), np.asarray(y_unroll), atol=1e-5, rtol=1e-5
        )
        # and the plan is actually binding: generous caps change the output
        y_free = stack.stack_train(params["stack"], cfg, x, None)
        assert not np.allclose(
            np.asarray(y_scan), np.asarray(y_free), atol=1e-5
        )


class TestPrefillDecodeParity:
    def test_prefill_and_decode_match_forward(self, monkeypatch):
        """Distinct per-layer schedules on the serving paths: prefill
        logits == training-stack forward logits at the last prompt
        position, and one decode step == forward on the extended
        sequence.  f32 compute/caches (test_archs convention) so any
        mismatch is a logic bug, not bf16 rounding; generous capacity so
        no tokens drop (capacity dropping is batch-dependent by design —
        a decode token competes with 1 step's tokens, a forward token
        with the whole sequence)."""
        import repro.models.layers as layers

        monkeypatch.setattr(layers, "COMPUTE_DTYPE", jnp.float32)
        cfg = _moe_cfg(n_layers=3, capacity_factor=8.0)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        table = ScheduleTable.from_schedules(_plans(3, seed=4))
        tokens = jax.random.randint(
            jax.random.PRNGKey(5), (2, 12), 0, cfg.vocab_size
        )
        logits_fwd = model.forward(params, tokens, schedule=table)

        caches = model.init_cache(2, 16, jnp.float32)
        logits_pre, caches = model.prefill(
            params, tokens, caches, schedule=table
        )
        np.testing.assert_allclose(
            np.asarray(logits_pre),
            np.asarray(logits_fwd[:, -1]),
            atol=1e-4,
            rtol=1e-4,
        )

        nxt = jnp.argmax(logits_pre, axis=-1).astype(jnp.int32)
        logits_dec, _ = model.decode_step(
            params, nxt, caches, jnp.int32(12), schedule=table
        )
        ext = jnp.concatenate([tokens, nxt[:, None]], axis=1)
        logits_fwd2 = model.forward(params, ext, schedule=table)
        np.testing.assert_allclose(
            np.asarray(logits_dec),
            np.asarray(logits_fwd2[:, -1]),
            atol=1e-4,
            rtol=1e-4,
        )


class TestVirtualFabricAdmission:
    """Scheduled capacity semantics on one device (the controller's
    virtual-rank convention)."""

    def setup_method(self):
        self.cfg = _moe_cfg(capacity_factor=8.0)
        self.params = moe.moe_init(jax.random.PRNGKey(0), self.cfg)
        self.x = jax.random.normal(
            jax.random.PRNGKey(1), (8, 64, 32), jnp.float32
        )

    def test_generous_plan_equals_dense(self):
        traffic = np.full((N_V, N_V), 1000.0)
        np.fill_diagonal(traffic, 0)
        row = ScheduleTable.from_schedules(
            [plan_schedule(decompose(traffic, "maxweight"))]
        ).row(0)
        y_row = moe.moe_apply(self.params, self.cfg, self.x, schedule=row)
        y_dense = moe._moe_dense(self.params, self.cfg, self.x)
        np.testing.assert_allclose(
            np.asarray(y_row), np.asarray(y_dense), atol=1e-6
        )

    def test_tight_plan_clips(self):
        tiny = np.full((N_V, N_V), 1.0)
        np.fill_diagonal(tiny, 0)
        row = ScheduleTable.from_schedules(
            [plan_schedule(decompose(tiny, "maxweight"), min_cap=1, quantum=1)]
        ).row(0)
        y_row = moe.moe_apply(self.params, self.cfg, self.x, schedule=row)
        y_dense = moe._moe_dense(self.params, self.cfg, self.x)
        assert not np.allclose(np.asarray(y_row), np.asarray(y_dense), atol=1e-6)

    def test_admission_matches_shipped_prefix(self):
        """The admission mask admits exactly the per-(pair, expert) slot
        prefix the static ppermute path would ship."""
        s = _plans(1, seed=6)[0]
        row = ScheduleTable.from_schedules([s]).row(0)
        e_local = self.cfg.moe.n_experts // N_V
        cap = np.asarray(row.pair_caps(e_local))
        per_expert = np.maximum(
            8, -(--(-s.caps.astype(np.int64) // e_local) // 8) * 8
        )
        np.testing.assert_array_equal(cap, s.cap_matrix(caps=per_expert))


class TestZeroRecompileSwap:
    @pytest.mark.parametrize("envelope_slack", [0.0, 1.5])
    def test_drift_swap_zero_compiles_in_train_loop(
        self, tmp_path, envelope_slack
    ):
        """THE tentpole regression: a drift-event schedule swap during
        scheduled-dispatch training performs zero recompiles — the
        re-planned table enters the same executable.  With a phase
        envelope (``envelope_slack > 0``) the ONE permitted exception is
        an envelope growth, and every compile must be accounted to one
        (``compiles == envelope_growths``); the legacy no-envelope config
        must stay strictly compile-free."""
        from repro.data import DataConfig
        from repro.train import TrainLoopConfig, train_loop

        cfg = _moe_cfg(n_layers=2)
        model = Model(cfg)
        rt = ScheduleRuntime(
            ControllerConfig(
                n_ranks=N_V, n_experts=8, ema=1.0, cooldown=2,
                envelope_slack=envelope_slack,
            ),
            model.n_moe_layers,
        )
        tokens = 8 * 32 * 2
        rt.prime(np.full((N_V, N_V), tokens / N_V**2))
        base = np.linspace(1.0, 2.0, 8)
        base /= base.sum()
        shift_at = 6

        def drift_hook(step, stats):
            probs = base if step < shift_at else base[::-1] ** 4 / (
                (base[::-1] ** 4).sum()
            )
            totals = stats.sum(axis=(1, 2), keepdims=True)
            return np.broadcast_to(probs[None, None, :], stats.shape) * totals

        res = train_loop(
            model,
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8),
            TrainLoopConfig(
                steps=14, ckpt_dir=str(tmp_path), ckpt_every=20,
                peak_lr=1e-3, warmup=4, log_every=5,
            ),
            runtime=rt,
            stats_hook=drift_hook,
        )
        ctl = res["controller"]
        assert ctl["swaps"] >= 1, ctl  # the drift actually swapped plans
        if envelope_slack:
            # every recompile is an accounted envelope growth, nothing else
            assert ctl["compiles"] == ctl["envelope_growths"], ctl
            assert ctl["envelope_growths"] <= 1, ctl
        else:
            assert ctl["compiles"] == 0, ctl  # strictly compile-free
            assert ctl["envelope_growths"] == 0, ctl
        assert np.isfinite(res["final_loss"])

    def test_jit_cache_stable_across_table_updates(self):
        cfg = _moe_cfg(n_layers=2)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size
        )
        batch = {"tokens": tokens, "targets": tokens}
        f = jax.jit(lambda p, b, s: model.loss(p, b, schedule=s))
        t1 = ScheduleTable.from_schedules(_plans(2, seed=7), k_max=N_V, clip=True)
        l1 = f(params, batch, t1)
        t2 = t1.update(_plans(2, seed=8))
        l2 = f(params, batch, t2)
        assert f._cache_size() == 1
        assert np.isfinite(float(l1)) and np.isfinite(float(l2))

    def test_runtime_table_cached_per_assignment(self):
        rt = ScheduleRuntime(
            ControllerConfig(n_ranks=N_V, n_experts=8, ema=1.0, cooldown=0),
            2,
        )
        with pytest.raises(ValueError, match="prime"):
            rt.table()
        rt.prime(np.full((N_V, N_V), 100.0))
        t1 = rt.table()
        assert rt.table() is t1  # cached while the assignment is stable
        rt.observe(
            np.broadcast_to(
                np.linspace(1, 64, 8)[None, None, :] ** 3, (2, 1, 8)
            ).copy()
        )
        t2 = rt.table()
        assert t2 is not t1
        assert all(
            a.shape == b.shape
            for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2))
        )


class TestGroupedLaunchInStack:
    def test_use_pallas_grouped_matches_einsum(self, monkeypatch):
        """The grouped single-launch kernel path (metadata prologue) must
        match the einsum path through a full scheduled forward (f32 so
        the comparison is kernel logic, not bf16 rounding)."""
        import repro.models.layers as layers

        monkeypatch.setattr(layers, "COMPUTE_DTYPE", jnp.float32)
        cfg = _moe_cfg(n_layers=2)
        cfg_p = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, use_pallas=True)
        )
        model, model_p = Model(cfg), Model(cfg_p)
        params = model.init(jax.random.PRNGKey(0))
        table = ScheduleTable.from_schedules(_plans(2, seed=9))
        tokens = jax.random.randint(
            jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size
        )
        y = model.forward(params, tokens, schedule=table)
        y_p = model_p.forward(params, tokens, schedule=table)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(y_p), atol=2e-4, rtol=2e-4
        )
