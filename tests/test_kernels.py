"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per kernel; tolerances depend on dtype (bf16 matmul
accumulates f32 in both kernel and ref, so errors stay small).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.moe_gemm import moe_gemm, moe_gemm_ref
from repro.kernels.rwkv_wkv import wkv6, wkv6_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------ moe_gemm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "e,c,d,f,bc,bf",
    [
        (2, 128, 64, 128, 128, 128),
        (4, 256, 128, 256, 128, 128),
        (1, 64, 32, 64, 64, 64),
        (3, 384, 128, 384, 128, 128),  # non-pow2 expert count / blocks
    ],
)
def test_moe_gemm_matches_ref(e, c, d, f, bc, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = (jax.random.normal(ks[0], (e, c, d)) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (e, d, f)) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (e, d, f)) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (e, f, d)) * 0.05).astype(dtype)
    out = moe_gemm(x, wg, wu, wd, block_c=bc, block_f=bf, interpret=True)
    ref = moe_gemm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_moe_gemm_zero_padding_rows():
    """Capacity padding rows (zeros) must produce zeros, not NaNs."""
    e, c, d, f = 2, 128, 64, 128
    x = jnp.zeros((e, c, d))
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    wg = jax.random.normal(ks[0], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[1], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[2], (e, f, d)) * 0.1
    out = moe_gemm(x, wg, wu, wd, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


# ------------------------------------------------- grouped launch (metadata)
def _grouped_inputs(e=4, c=128, d=64, f=128, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (e, c, d)) * 0.5
    wg = jax.random.normal(ks[1], (e, d, f)) * 0.05
    wu = jax.random.normal(ks[2], (e, d, f)) * 0.05
    wd = jax.random.normal(ks[3], (e, f, d)) * 0.05
    return x, wg, wu, wd


def test_moe_gemm_grouped_valid_rows_match_ref():
    """The group-metadata prologue is a compute-skip hint: valid rows are
    bit-for-bit the ungrouped kernel's values; fully invalid row blocks
    are zeros."""
    x, wg, wu, wd = _grouped_inputs()
    counts = [128, 64, 0, 8]  # full / half / empty / one-block prefix
    rv = np.zeros((4, 128), bool)
    for i, ct in enumerate(counts):
        rv[i, :ct] = True
    rv = jnp.asarray(rv)
    out = moe_gemm(x, wg, wu, wd, row_valid=rv, block_c=64, block_f=64)
    ref = moe_gemm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(out * rv[..., None], np.float32),
        np.asarray(ref * rv[..., None], np.float32),
        rtol=2e-5, atol=2e-5,
    )
    assert float(jnp.abs(out[2]).max()) == 0.0  # empty group skipped
    assert float(jnp.abs(out[3, 64:]).max()) == 0.0  # empty tail block


def test_moe_gemm_grouped_partial_block_computes_everything():
    """Rows of a partially occupied block are all computed (callers gate
    invalid slots to zero) — the hint never changes valid-row values."""
    x, wg, wu, wd = _grouped_inputs(seed=1)
    rv = jnp.zeros((4, 128), bool).at[:, :8].set(True)  # 8 of 64 per block
    out = moe_gemm(x, wg, wu, wd, row_valid=rv, block_c=64, block_f=64)
    ref = moe_gemm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(
        np.asarray(out[:, :64], np.float32),
        np.asarray(ref[:, :64], np.float32),
        rtol=2e-5, atol=2e-5,
    )
    assert float(jnp.abs(out[:, 64:]).max()) == 0.0


def test_moe_gemm_grouped_grads_match_oracle():
    """custom_vjp: grouped forward + the Pallas dgrad/wgrad backward
    (PR 8) — grads of a gate-masked loss match the pure-oracle grads."""
    x, wg, wu, wd = _grouped_inputs(seed=2)
    rv = jnp.zeros((4, 128), bool).at[:, :64].set(True)
    mask = rv[..., None].astype(x.dtype)

    def loss_kernel(x, wg, wu, wd):
        y = moe_gemm(x, wg, wu, wd, row_valid=rv, block_c=64, block_f=64)
        return ((y * mask) ** 2).sum()

    def loss_ref(x, wg, wu, wd):
        return ((moe_gemm_ref(x, wg, wu, wd) * mask) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, wg, wu, wd)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-4, atol=1e-4,
        )


# -------------------------------------------------- Pallas backward (PR 8)
def test_backward_block_f_selected_for_test_shape():
    """The shapes this file sweeps must take the Pallas backward, not
    the oracle fallback — otherwise the grad tests above prove nothing
    about the kernels."""
    from repro.kernels.moe_gemm import select_backward_block_f

    assert select_backward_block_f(128, 64, 128, 64, interpret=True) == 128
    # production table hit
    assert select_backward_block_f(2048, 4096, 14336, 512) == 128
    # block_c not dividing C: the shared occupancy-table layout breaks
    assert select_backward_block_f(100, 64, 128, 64, interpret=True) is None
    # compiled mode with no >=128 divisor of f: untileable -> oracle
    assert select_backward_block_f(256, 64, 24, 128, interpret=False) is None


def test_moe_gemm_ungrouped_pallas_backward_matches_ref_vjp():
    """The ungrouped kernel's Pallas backward (full occupancy) against
    jax's own VJP of the einsum oracle, unmasked cotangent."""
    x, wg, wu, wd = _grouped_inputs(seed=3)
    g = jax.random.normal(jax.random.PRNGKey(9), x.shape) * 0.3

    def run(fn):
        out, vjp = jax.vjp(fn, x, wg, wu, wd)
        return out, vjp(g)

    out_k, gk = run(lambda *a: moe_gemm(*a, block_c=64, block_f=64, interpret=True))
    out_r, gr = run(moe_gemm_ref)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4
        )


def test_moe_gemm_pallas_backward_vs_oracle_backward_factory():
    """Same forward, both backward flavors of the grouped custom_vjp —
    the Pallas dgrad/wgrad pair against the einsum-oracle VJP it
    replaces, on a partially occupied grid with gate-masked cotangents
    (the only regime where the oracle is valid)."""
    from repro.kernels.moe_gemm.ops import (
        _differentiable_grouped_kernel,
        row_block_meta,
    )

    x, wg, wu, wd = _grouped_inputs(seed=4)
    rv = np.zeros((4, 128), bool)
    for i, ct in enumerate([128, 64, 0, 8]):
        rv[i, :ct] = True
    rv = jnp.asarray(rv)
    meta = row_block_meta(rv, 64)
    mask = rv[..., None].astype(x.dtype)

    def loss(kernel):
        def f(x, wg, wu, wd):
            return ((kernel(meta, x, wg, wu, wd) * mask) ** 2).sum()
        return jax.grad(f, argnums=(0, 1, 2, 3))(x, wg, wu, wd)

    g_pallas = loss(_differentiable_grouped_kernel(64, 64, True, 64))
    g_oracle = loss(_differentiable_grouped_kernel(64, 64, True, None))
    for a, b in zip(g_pallas, g_oracle):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4
        )


def test_moe_gemm_grouped_dark_block_dgrad_is_exact_zero():
    """Dark row blocks (zero occupancy) produced constant-zero forward
    output, so their input cotangent is exactly zero — the backward
    skips them like the forward did, even when the upstream cotangent
    there is garbage (unmasked).  This is where the Pallas backward is
    MORE faithful than the oracle, which backprops rows that were never
    computed."""
    from repro.kernels.moe_gemm.ops import (
        _differentiable_grouped_kernel,
        row_block_meta,
    )

    x, wg, wu, wd = _grouped_inputs(seed=5)
    rv = jnp.zeros((4, 128), bool).at[:2, :].set(True)  # experts 2,3 dark
    meta = row_block_meta(rv, 64)
    kernel = _differentiable_grouped_kernel(64, 64, True, 64)
    out, vjp = jax.vjp(lambda *a: kernel(meta, *a), x, wg, wu, wd)
    g = jnp.ones_like(out)  # garbage upstream cotangent on dark rows
    dx, dwg, dwu, dwd = vjp(g)
    assert float(jnp.abs(dx[2:]).max()) == 0.0
    assert float(jnp.abs(dwg[2:]).max()) == 0.0
    assert float(jnp.abs(dwu[2:]).max()) == 0.0
    assert float(jnp.abs(dwd[2:]).max()) == 0.0
    # the live experts still get real grads
    assert float(jnp.abs(dx[:2]).max()) > 0.0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_dgrad_wgrad_kernels_match_ref_vjp(dtype):
    """The raw dgrad/wgrad launches at full occupancy against the
    oracle VJP, both dtypes (kernels accumulate f32 either way)."""
    from repro.kernels.moe_gemm import (
        moe_gemm_grouped_pallas_dgrad,
        moe_gemm_grouped_pallas_wgrad,
    )

    e, c, d, f = 2, 128, 64, 128
    x, wg, wu, wd = (a.astype(dtype) for a in _grouped_inputs(e, c, d, f, seed=6))
    g = (jax.random.normal(jax.random.PRNGKey(7), (e, c, d)) * 0.3).astype(dtype)
    meta = jnp.full((e * (c // 64),), 64, jnp.int32)
    dx = moe_gemm_grouped_pallas_dgrad(
        g, x, meta, wg, wu, wd, block_c=64, block_f=64, interpret=True
    )
    dwg, dwu, dwd = moe_gemm_grouped_pallas_wgrad(
        g, x, meta, wg, wu, wd, block_c=64, block_f=64, interpret=True
    )
    _, vjp = jax.vjp(moe_gemm_ref, x, wg, wu, wd)
    refs = vjp(g)
    for a, b in zip((dx, dwg, dwu, dwd), refs):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), **_tol(dtype)
        )


def test_moe_gemm_backward_block_shape_independent():
    """Backward values must not depend on the backward f tile."""
    x, wg, wu, wd = _grouped_inputs(seed=8)
    rv = jnp.zeros((4, 128), bool).at[:, :96].set(True)
    mask = rv[..., None].astype(x.dtype)
    from repro.kernels.moe_gemm.ops import (
        _differentiable_grouped_kernel,
        row_block_meta,
    )

    meta = row_block_meta(rv, 32)

    def grads(bwd_bf):
        kernel = _differentiable_grouped_kernel(32, 64, True, bwd_bf)
        def f(x, wg, wu, wd):
            return ((kernel(meta, x, wg, wu, wd) * mask) ** 2).sum()
        return jax.grad(f, argnums=(0, 1, 2, 3))(x, wg, wu, wd)

    for a, b in zip(grads(32), grads(128)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5
        )


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,sq,skv,d,window",
    [
        (1, 4, 4, 256, 256, 64, None),  # MHA causal
        (2, 8, 2, 256, 256, 64, None),  # GQA 4:1
        (1, 4, 1, 128, 128, 64, None),  # MQA
        (1, 4, 4, 256, 256, 64, 96),  # sliding window
        (1, 2, 2, 128, 512, 64, None),  # decode-ish: kv longer than q
    ],
)
def test_flash_matches_ref(b, h, kv, sq, skv, d, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(ks[0], (b, h, sq, d))).astype(dtype)
    k = (jax.random.normal(ks[1], (b, kv, skv, d))).astype(dtype)
    v = (jax.random.normal(ks[2], (b, kv, skv, d))).astype(dtype)
    out = flash_attention(
        q, k, v, window=window, block_q=128, block_k=128, interpret=True
    )
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_block_shape_independent():
    """Output must not depend on the block decomposition."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    a = flash_attention(q, k, v, block_q=256, block_k=256, interpret=True)
    b = flash_attention(q, k, v, block_q=64, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,t,d,bt",
    [
        (1, 2, 64, 32, 32),
        (2, 4, 128, 64, 64),
        (1, 1, 96, 16, 32),  # t not multiple of 64
    ],
)
def test_wkv6_matches_ref(b, h, t, d, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = (jax.random.normal(ks[0], (b, h, t, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, h, t, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (b, h, t, d)) * 0.5).astype(dtype)
    # decay in (0,1), realistic RWKV6 range
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, d))) * 0.5 + 0.45
    w = w.astype(jnp.float32)
    u = (jax.random.normal(ks[4], (h, d)) * 0.1).astype(jnp.float32)
    y, s = wkv6(r, k, v, w, u, block_t=bt, interpret=True)
    y_ref, s_ref = wkv6_ref(r, k, v, w, u)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), **tol)


def test_wkv6_state_carries_across_blocks():
    """Splitting T into more blocks must not change the result (state
    persists in scratch across sequential grid steps)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    b, h, t, d = 1, 2, 128, 32
    r = jax.random.normal(ks[0], (b, h, t, d)) * 0.5
    k = jax.random.normal(ks[1], (b, h, t, d)) * 0.5
    v = jax.random.normal(ks[2], (b, h, t, d)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, t, d))) * 0.5 + 0.45
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    y1, s1 = wkv6(r, k, v, w, u, block_t=128, interpret=True)
    y2, s2 = wkv6(r, k, v, w, u, block_t=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5, atol=1e-5)
