"""Simulator tests: invariants + the paper's qualitative claims (§4.2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic in-repo sweep
    from _hyp_compat import given, settings
    from _hyp_compat import strategies as st

from repro.core import (
    CommModel,
    decompose,
    gen_trace,
    knee_model,
    linear_model,
    order_phases,
    simulate_decomposition,
    simulate_ideal,
    simulate_sequential,
)

COMM = CommModel(tokens_per_us=100.0, reconf_us=0.01)
KNEE = knee_model()
LINEAR = linear_model()


def _skewed(rng, n=8, scale=4000):
    m = np.floor(rng.random((n, n)) ** 4 * scale)
    np.fill_diagonal(m, 0.0)
    return m


class TestSimulatorInvariants:
    def test_zero_matrix(self):
        d = decompose(np.zeros((8, 8)), "maxweight")
        r = simulate_decomposition(d, KNEE, COMM)
        assert r.makespan_us == 0.0

    def test_makespan_at_least_compute(self):
        rng = np.random.default_rng(0)
        for strat in ("bvn", "maxweight", "shift"):
            m = _skewed(rng)
            d = decompose(m, strat)
            r = simulate_decomposition(d, KNEE, COMM)
            assert r.makespan_us >= r.compute_us - 1e-9

    def test_makespan_at_least_network_lower_bound(self):
        """Per-phase circuit hold times are a hard lower bound."""
        rng = np.random.default_rng(1)
        m = _skewed(rng)
        d = decompose(m, "maxweight")
        r = simulate_decomposition(d, KNEE, COMM)
        assert r.makespan_us >= r.dispatch_us - 1e-9

    def test_single_fabric_slower_or_equal_dual(self):
        rng = np.random.default_rng(2)
        for _ in range(5):
            m = _skewed(rng)
            d = decompose(m, "maxweight")
            dual = simulate_decomposition(d, KNEE, COMM, fabric="dual")
            single = simulate_decomposition(d, KNEE, COMM, fabric="single")
            assert single.makespan_us >= dual.makespan_us - 1e-6

    def test_ideal_lower_bounds_ring(self):
        rng = np.random.default_rng(3)
        for _ in range(5):
            m = _skewed(rng)
            assert (
                simulate_ideal(m, LINEAR, COMM).makespan_us
                <= simulate_sequential(m, LINEAR, COMM).makespan_us + 1e-6
            )

    def test_local_tokens_extend_compute(self):
        m = np.zeros((4, 4))
        m[0, 1] = 1000.0
        d = decompose(m, "maxweight")
        base = simulate_decomposition(d, LINEAR, COMM)
        heavy_local = simulate_decomposition(
            d, LINEAR, COMM, local_tokens=np.array([0.0, 1e6, 0.0, 0.0])
        )
        assert heavy_local.makespan_us > base.makespan_us

    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_overlap_never_hurts_with_linear_compute(self, seed):
        """With no fixed overhead, per-phase compute is free to pipeline:
        overlapped makespan <= non-overlapped."""
        rng = np.random.default_rng(seed)
        m = _skewed(rng, n=6)
        d = decompose(m, "maxweight")
        ovl = simulate_decomposition(d, LINEAR, COMM, overlap=True)
        seq = simulate_decomposition(d, LINEAR, COMM, overlap=False)
        assert ovl.makespan_us <= seq.makespan_us + 1e-6


class TestPaperClaims:
    """Trace-driven versions of the paper's §4.2 findings."""

    def _mean_makespan(self, mats, strat, compute, overlap=True):
        out = []
        for m in mats:
            d = decompose(m, strat)
            out.append(
                simulate_decomposition(
                    d,
                    compute,
                    COMM,
                    overlap=overlap,
                    local_tokens=d.meta["local_tokens"],
                ).makespan_us
            )
        return float(np.mean(out))

    def test_bvn_more_phases_than_maxweight(self):
        mats = gen_trace("mixtral-8x22b", "speed", iterations=8, seed=0)
        for m in mats:
            bvn = decompose(m, "bvn")
            mw = decompose(m, "maxweight")
            assert bvn.num_phases > mw.num_phases

    def test_small_batch_overlapped_bvn_worse_than_nonoverlapped(self):
        """Fig 3: with knee costs + small batches, overlapping BvN's tiny
        phases accumulates fixed overheads and loses to non-overlap."""
        mats = gen_trace("mixtral-8x22b", "mmlu", iterations=12, seed=1)
        ovl = self._mean_makespan(mats, "bvn", KNEE, overlap=True)
        seq = self._mean_makespan(mats, "bvn", KNEE, overlap=False)
        assert ovl > seq

    def test_large_batch_maxweight_beats_bvn(self):
        """Fig 4: large batches amortize the knee; MW's few dense phases
        win over BvN's fragmentation."""
        mats = gen_trace("mixtral-8x22b", "speed", iterations=12, seed=2)
        mw = self._mean_makespan(mats, "maxweight", KNEE)
        bvn = self._mean_makespan(mats, "bvn", KNEE)
        assert mw < bvn

    def test_large_batch_maxweight_approaches_ideal(self):
        """Fig 4: MW+overlap approaches (or beats) the non-overlapped
        congestion-free ideal."""
        mats = gen_trace("mixtral-8x22b", "speed", iterations=12, seed=3)
        mw = self._mean_makespan(mats, "maxweight", KNEE)
        ideal = float(
            np.mean([simulate_ideal(m, KNEE, COMM).makespan_us for m in mats])
        )
        assert mw <= 1.25 * ideal

    def test_small_batch_static_ring_competitive(self):
        """Fig 3: under small batches even the congestion-prone static ring
        can beat fragmented decompositions (BvN overlapped)."""
        mats = gen_trace("mixtral-8x22b", "mmlu", iterations=12, seed=4)
        ring = float(
            np.mean([simulate_sequential(m, KNEE, COMM).makespan_us for m in mats])
        )
        bvn_ovl = self._mean_makespan(mats, "bvn", KNEE, overlap=True)
        assert ring < bvn_ovl


class TestOrdering:
    @pytest.mark.parametrize("how", ["lpt", "spt", "johnson3", "asis"])
    def test_reorder_preserves_delivery(self, how):
        rng = np.random.default_rng(5)
        m = _skewed(rng)
        d = order_phases(decompose(m, "maxweight"), how)
        d.verify()

    def test_lpt_no_worse_than_spt_on_average(self):
        """Big-phases-first exposes long compute windows early (§3.3)."""
        rng = np.random.default_rng(6)
        lpt_wins = 0
        trials = 20
        for _ in range(trials):
            m = _skewed(rng)
            d = decompose(m, "maxweight")
            lpt = simulate_decomposition(order_phases(d, "lpt"), KNEE, COMM)
            spt = simulate_decomposition(order_phases(d, "spt"), KNEE, COMM)
            if lpt.makespan_us <= spt.makespan_us + 1e-9:
                lpt_wins += 1
        assert lpt_wins >= trials * 0.6
