"""Subprocess wrapper for multi-device tests.

The main pytest process must keep exactly 1 CPU device (smoke tests and
benches depend on it), so anything needing a real multi-device mesh runs
in a child process with ``--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", script)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed\nstdout:\n{proc.stdout[-4000:]}\n"
            f"stderr:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.mark.slow
def test_moe_dispatch_equivalence():
    out = _run("multidev_moe.py")
    assert "ALL MULTIDEVICE CHECKS PASSED" in out


@pytest.mark.slow
def test_fabric_parity_matrix():
    out = _run("multidev_fabric.py")
    assert "ALL FABRIC MATRIX CHECKS PASSED" in out


@pytest.mark.slow
def test_train_loop_fault_tolerance():
    out = _run("multidev_train.py")
    assert "ALL TRAIN CHECKS PASSED" in out


@pytest.mark.slow
def test_pipeline_parallelism():
    out = _run("multidev_pipeline.py")
    assert "ALL PIPELINE CHECKS PASSED" in out
