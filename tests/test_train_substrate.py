"""Optimizer / data / checkpoint / train-step unit tests."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic in-repo sweep
    from _hyp_compat import given, settings
    from _hyp_compat import strategies as st

from repro.checkpoint import CheckpointManager, restore, save
from repro.data import DataConfig, SyntheticStream
from repro.optim import AdamW, cosine_schedule, ef_int8_compress, ef_int8_init
from repro.optim.adamw import global_norm


# ----------------------------------------------------------------- optimizer
class TestAdamW:
    def test_quadratic_converges(self):
        opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
        params = {"x": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        loss = lambda p: jnp.sum(p["x"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = opt.update(g, state, params)
        assert float(loss(params)) < 1e-3

    def test_clip_norm(self):
        opt = AdamW(lr=0.0, clip_norm=1.0)
        params = {"x": jnp.zeros(3)}
        state = opt.init(params)
        g = {"x": jnp.array([100.0, 0.0, 0.0])}
        _, _, stats = opt.update(g, state, params)
        assert float(stats["grad_norm"]) == pytest.approx(100.0)

    def test_weight_decay_only_matrices(self):
        opt = AdamW(lr=0.1, weight_decay=1.0, clip_norm=None)
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        state = opt.init(params)
        zero_g = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = opt.update(zero_g, state, params)
        assert float(jnp.abs(new["w"] - 1.0).max()) > 0.0  # decayed
        assert float(jnp.abs(new["b"] - 1.0).max()) == 0.0  # exempt

    def test_cosine_schedule_shape(self):
        lr = cosine_schedule(1.0, 10, 100, final_frac=0.1)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
        assert float(lr(55)) < float(lr(20))


# --------------------------------------------------------------- compression
class TestCompression:
    def test_roundtrip_small_error(self):
        g = {"w": jnp.linspace(-1, 1, 256)}
        ef = ef_int8_init(g)
        deq, ef = ef_int8_compress(g, ef)
        assert float(jnp.abs(deq["w"] - g["w"]).max()) < 1e-2

    def test_error_feedback_unbiased_over_time(self):
        """Repeatedly compressing the same gradient: the SUM of delivered
        gradients tracks the sum of true gradients (EF property)."""
        g = {"w": jnp.array([0.3e-3, -1.7e-3, 0.9e-3, 2.2e-3])}
        ef = ef_int8_init(g)
        delivered = jnp.zeros(4)
        n = 50
        for _ in range(n):
            deq, ef = ef_int8_compress(g, ef)
            delivered += deq["w"]
        np.testing.assert_allclose(
            np.asarray(delivered / n), np.asarray(g["w"]), rtol=0.02, atol=1e-6
        )

    def test_sgd_with_ef8_matches_uncompressed_direction(self):
        key = jax.random.PRNGKey(0)
        w_true = jax.random.normal(key, (16,))
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        y = x @ w_true
        loss = lambda w: jnp.mean((x @ w - y) ** 2)
        w_a = jnp.zeros(16)
        w_b = jnp.zeros(16)
        ef = ef_int8_init({"w": w_b})
        for _ in range(600):
            g = jax.grad(loss)(w_a)
            w_a -= 0.01 * g
            g2 = jax.grad(loss)(w_b)
            deq, ef = ef_int8_compress({"w": g2}, ef)
            w_b -= 0.01 * deq["w"]
        assert float(loss(w_a)) < 1e-3
        # EF compression converges to comparable loss (within 5x)
        assert float(loss(w_b)) < max(5 * float(loss(w_a)), 1e-3)


# ----------------------------------------------------------------------- data
class TestData:
    def test_deterministic_and_resumable(self):
        cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=7)
        s1, s2 = SyntheticStream(cfg), SyntheticStream(cfg)
        for step in (0, 5, 1000):
            b1, b2 = s1.batch(step), s2.batch(step)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4)
        s = SyntheticStream(cfg)
        assert not np.array_equal(s.batch(0)["tokens"], s.batch(1)["tokens"])

    def test_targets_shifted(self):
        cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=2)
        b = SyntheticStream(cfg).batch(3)
        np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
        assert (b["targets"][:, -1] == -1).all()

    def test_host_slice(self):
        cfg = DataConfig(vocab_size=97, seq_len=8, global_batch=8)
        s = SyntheticStream(cfg)
        full = s.batch(0)
        part = s.batch(0, host_slice=slice(2, 6))
        np.testing.assert_array_equal(part["tokens"], full["tokens"][2:6])

    def test_frontend_embeds(self):
        cfg = DataConfig(
            vocab_size=97, seq_len=16, global_batch=2, frontend_tokens=4, d_model=8
        )
        b = SyntheticStream(cfg).batch(0)
        assert b["ext_embeds"].shape == (2, 4, 8)
        assert b["tokens"].shape == (2, 12)

    @given(st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=20, deadline=None)
    def test_property_pure_function_of_step(self, step):
        cfg = DataConfig(vocab_size=31, seq_len=8, global_batch=2, seed=3)
        b1 = SyntheticStream(cfg).batch(step)
        b2 = SyntheticStream(cfg).batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


# ----------------------------------------------------------------- checkpoint
class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {
            "params": {"w": jax.random.normal(k, (4, 4)), "b": jnp.ones(4)},
            "opt": {"step": jnp.int32(7), "mu": {"w": jnp.zeros((4, 4))}},
        }

    def test_save_restore_roundtrip(self, tmp_path):
        tree = self._tree()
        path = save(str(tmp_path), 7, tree)
        template = jax.tree.map(jnp.zeros_like, tree)
        out = restore(path, template)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_manager_keep_and_latest(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree()
        for s in (10, 20, 30):
            m.save(s, tree)
        assert m.steps() == [20, 30]
        assert m.latest_step() == 30

    def test_async_save(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        m.save_async(5, self._tree())
        m.wait()
        assert m.latest_step() == 5

    def test_partial_checkpoint_ignored(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=3)
        m.save(10, self._tree())
        # simulate a crash mid-write: directory without manifest
        os.makedirs(tmp_path / "step_00000020")
        assert m.latest_step() == 10

    def test_shape_mismatch_raises(self, tmp_path):
        path = save(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            restore(path, {"w": jnp.zeros((3, 3))})

    def test_restore_latest_none_when_empty(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        step, tree = m.restore_latest({"x": jnp.zeros(1)})
        assert step is None and tree is None


# ------------------------------------------------------------------ train_step
class TestTrainStep:
    def test_microbatch_accumulation_matches_full(self):
        from repro.configs import smoke_config
        from repro.models import Model
        from repro.train import make_train_step

        cfg = smoke_config("granite-3-8b")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3, clip_norm=None, weight_decay=0.0)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
        batch = {
            "tokens": tokens,
            "targets": jnp.roll(tokens, -1, 1).at[:, -1].set(-1),
        }
        outs = {}
        for mb in (1, 2):
            step = make_train_step(model, opt, microbatches=mb)
            p, s, _, metrics = jax.jit(step)(
                params, opt.init(params), {}, batch
            )
            outs[mb] = (metrics["loss"], p)
        # bf16 forward: small tolerance on loss, params close
        assert float(jnp.abs(outs[1][0] - outs[2][0])) < 2e-2
        for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[2][1])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2
            )

    def test_loss_decreases_over_steps(self):
        from repro.configs import smoke_config
        from repro.data import DataConfig, SyntheticStream
        from repro.models import Model
        from repro.train import make_train_step

        cfg = smoke_config("qwen2-1.5b")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=3e-3)
        state = opt.init(params)
        step_fn = jax.jit(make_train_step(model, opt))
        stream = SyntheticStream(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
        )
        losses = []
        for i in range(30):
            b = stream.batch(i)
            params, state, _, m = step_fn(params, state, {}, b)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


# ------------------------------------------------------- fault-tolerant loop
class TestTrainLoopFaultTolerance:
    """Retry-path regressions: duplicate-free history after rollback, a
    consecutive (not cumulative) failure budget, and honest per-step
    throughput in the history."""

    def _run(self, tmp_path, *, steps, failure_hook=None, max_failures=3,
             ckpt_every=4, log_every=1):
        from repro.configs.base import ModelConfig
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = ModelConfig(
            name="loop-test", family="dense", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64, remat="none",
        )
        return train_loop(
            Model(cfg),
            DataConfig(vocab_size=64, seq_len=16, global_batch=4),
            TrainLoopConfig(
                steps=steps, ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                keep=3, peak_lr=1e-3, warmup=2, log_every=log_every,
                max_failures=max_failures,
            ),
            failure_hook=failure_hook,
        )

    def test_rollback_dedupes_history(self, tmp_path):
        """A failure past a checkpoint replays steps; the returned history
        must not contain duplicate step numbers."""
        state = {"fired": False}

        def boom(step):
            if step == 6 and not state["fired"]:
                state["fired"] = True
                raise RuntimeError("injected fault")

        res = self._run(tmp_path, steps=10, failure_hook=boom)
        assert state["fired"] and res["failures"] == 1
        steps = [h["step"] for h in res["history"]]
        assert steps == sorted(set(steps)), steps
        assert res["final_step"] == 10

    def test_transient_faults_spread_across_run_survive(self, tmp_path):
        """More total faults than max_failures, but each retry succeeds:
        the consecutive budget must NOT kill the run (the old cumulative
        counter did)."""
        fired = set()

        def boom(step):
            if step in (3, 5, 7, 9) and step not in fired:
                fired.add(step)
                raise RuntimeError(f"transient fault @ {step}")

        res = self._run(tmp_path, steps=12, failure_hook=boom, max_failures=2)
        assert len(fired) == 4
        assert res["failures"] == 4  # total is still reported
        assert res["final_step"] == 12

    def test_persistent_failure_exhausts_budget(self, tmp_path):
        """A step that keeps failing must still raise after max_failures
        consecutive attempts."""
        attempts = []

        def boom(step):
            if step == 5:
                attempts.append(step)
                raise RuntimeError("persistent fault")

        with pytest.raises(RuntimeError, match="persistent fault"):
            self._run(tmp_path, steps=10, failure_hook=boom, max_failures=2)
        assert len(attempts) == 3  # budget + the final fatal attempt

    def test_history_dt_is_per_step(self, tmp_path):
        """history[*]['dt_s'] must be per-step time, not the whole
        log_every window (the old behavior over-reported by log_every x)."""
        sleep_s = 0.05

        def slow(step):
            time.sleep(sleep_s)

        res = self._run(
            tmp_path, steps=11, failure_hook=slow, log_every=5
        )
        entries = {h["step"]: h["dt_s"] for h in res["history"]}
        # steady-state windows (steps 1-5 and 6-10) cover 5 steps each of
        # >= 50ms: per-step must sit near one step's cost, far below the
        # ~250ms window total the bug reported
        for s in (5, 10):
            assert sleep_s <= entries[s] < 3 * sleep_s, entries


class TestNonFiniteLoss:
    """PR 6 satellite: a NaN/Inf loss is a *failed step*, not a number to
    log — it must consume the failure budget through the same rollback
    path a crash does (the old loop logged the NaN and kept training on
    poisoned optimizer state)."""

    def test_nan_loss_consumes_failure_budget(self, tmp_path):
        from repro.configs.base import ModelConfig
        from repro.core import NonFiniteLossError
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = ModelConfig(
            name="nan-test", family="dense", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64, remat="none",
        )
        # an absurd peak LR diverges to NaN within a couple of steps;
        # rollback replays the same data and LR schedule, so the NaN is
        # persistent and must exhaust the consecutive-failure budget
        with pytest.raises(NonFiniteLossError, match="non-finite loss"):
            train_loop(
                Model(cfg),
                DataConfig(vocab_size=64, seq_len=16, global_batch=4),
                TrainLoopConfig(
                    steps=10, ckpt_dir=str(tmp_path), ckpt_every=4, keep=3,
                    peak_lr=1e6, warmup=2, log_every=1, max_failures=2,
                ),
            )

    def test_healthy_run_logs_only_finite_losses(self, tmp_path):
        from repro.configs.base import ModelConfig
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = ModelConfig(
            name="nan-test", family="dense", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64, remat="none",
        )
        res = train_loop(
            Model(cfg),
            DataConfig(vocab_size=64, seq_len=16, global_batch=4),
            TrainLoopConfig(
                steps=8, ckpt_dir=str(tmp_path), ckpt_every=4,
                peak_lr=1e-3, warmup=2, log_every=1,
            ),
        )
        assert res["failures"] == 0
        losses = [h["loss"] for h in res["history"]]
        assert losses and all(np.isfinite(losses)), losses
