"""Unit tests for the loop-aware HLO analyzer (launch/hlo.py)."""

import textwrap

from repro.launch.hlo import analyze_module, parse_module, _multipliers

HLO = textwrap.dedent("""\
    HloModule jit_f, num_partitions=4

    %body.1 (p.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p.1 = (s32[], f32[8,16]{1,0}) parameter(0)
      %gte.1 = s32[] get-tuple-element(%p.1), index=0
      %gte.2 = f32[8,16]{1,0} get-tuple-element(%p.1), index=1
      %c1 = s32[] constant(1)
      %add.1 = s32[] add(%gte.1, %c1)
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%gte.2, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar.1 = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[1,4]<=[4], to_apply=%sum.1
      ROOT %tup.1 = (s32[], f32[8,16]{1,0}) tuple(%add.1, %ar.1)
    }

    %cond.1 (p.2: (s32[], f32[8,16])) -> pred[] {
      %p.2 = (s32[], f32[8,16]{1,0}) parameter(0)
      %gte.3 = s32[] get-tuple-element(%p.2), index=0
      %c10 = s32[] constant(10)
      ROOT %lt.1 = pred[] compare(%gte.3, %c10), direction=LT
    }

    %sum.1 (a.1: f32[], b.1: f32[]) -> f32[] {
      %a.1 = f32[] parameter(0)
      %b.1 = f32[] parameter(1)
      ROOT %s.1 = f32[] add(%a.1, %b.1)
    }

    ENTRY %main.1 (arg.1: f32[8,16]) -> f32[8,16] {
      %arg.1 = f32[8,16]{1,0} parameter(0)
      %c0 = s32[] constant(0)
      %tup.0 = (s32[], f32[8,16]{1,0}) tuple(%c0, %arg.1)
      %while.1 = (s32[], f32[8,16]{1,0}) while(%tup.0), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out.1 = f32[8,16]{1,0} get-tuple-element(%while.1), index=1
    }
""")


class TestParsing:
    def test_computations_found(self):
        comps = parse_module(HLO)
        assert set(comps) == {"body.1", "cond.1", "sum.1", "main.1"}
        assert comps["main.1"].is_entry

    def test_multipliers_use_trip_count(self):
        comps = parse_module(HLO)
        mult = _multipliers(comps)
        assert mult["main.1"] == 1.0
        assert mult["body.1"] == 10.0
        assert mult["cond.1"] == 11.0


class TestCosts:
    def test_dot_flops_scaled_by_loop(self):
        a = analyze_module(HLO, n_devices=4)
        # dot [8,16]x[16,16]: 2*8*16*16 = 4096 flops, x10 iterations
        assert a["flops"] == 4096 * 10

    def test_allreduce_bytes_scaled(self):
        a = analyze_module(HLO, n_devices=4)
        # result 8*16*4 = 512 B, 10 iterations
        assert a["collectives"]["all-reduce"] == 512 * 10
        # ring wire model: 2*size*(S-1)/S with S=4
        assert a["wire"]["all-reduce"] == int(2 * 512 * 3 / 4) * 10

    def test_counts(self):
        a = analyze_module(HLO, n_devices=4)
        assert a["collective_counts"]["all-reduce"] == 10.0


class TestPermutePairs:
    def test_sparse_permute_fraction(self):
        hlo = textwrap.dedent("""\
            HloModule jit_g, num_partitions=4

            ENTRY %main.2 (x.1: bf16[128]) -> bf16[128] {
              %x.1 = bf16[128]{0} parameter(0)
              ROOT %cp.1 = bf16[128]{0} collective-permute(%x.1), source_target_pairs={{0,1},{1,0}}
            }
        """)
        a = analyze_module(hlo, n_devices=4)
        assert a["permute_pair_fraction"] == 0.5
        # wire bytes scaled by the pair fraction (idle pairs stay dark)
        assert a["wire"]["collective-permute"] == int(128 * 2 * 0.5)
