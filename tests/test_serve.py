"""repro.serve: continuous-batching decode service (PR 10).

Three layers of coverage:

* host-side units — the length-bucketed ``RequestQueue``, the
  slot-recycling ``ContinuousBatcher``, and ``ServeMetrics``;
* token parity — the engine's batched, slot-recycled, padding-masked
  serving path must produce EXACTLY the tokens a straight per-request
  prefill + scalar-decode reference produces, and the per-slot
  ``[B]``-step decode path must match per-row scalar decode on ragged
  depths;
* the drifting e2e smoke — a probed A → B → A token-mix drift must
  drive the in-graph controller through cold re-plans (regime miss) and
  at least one schedule-regime warm swap (regime return), with the
  decode executable compiled exactly once for the whole run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.serve import (
    ContinuousBatcher,
    Request,
    RequestQueue,
    ServeEngine,
    ServeMetrics,
    percentiles,
)


def _moe_cfg(arch="mixtral-8x7b", dispatch="scheduled"):
    cfg = smoke_config(arch)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch=dispatch)
    )


def _requests(rng, vocab, specs, pool=None):
    out = []
    for plen, mnew in specs:
        toks = (
            rng.choice(pool, plen) if pool is not None
            else rng.integers(0, vocab, plen)
        )
        out.append(Request(prompt=toks, max_new_tokens=mnew, arrival=0.0))
    return out


# ------------------------------------------------------------------- queue
class TestRequestQueue:
    def test_bucket_of_picks_smallest_fit(self):
        q = RequestQueue(buckets=(8, 16, 32))
        assert q.bucket_of(0) == 8  # 1-token prompt: empty prefill
        assert q.bucket_of(8) == 8
        assert q.bucket_of(9) == 16
        assert q.bucket_of(33) is None

    def test_add_rejects_over_largest_bucket(self):
        q = RequestQueue(buckets=(4,))
        assert q.add(Request(prompt=np.arange(5), max_new_tokens=1))
        assert not q.add(Request(prompt=np.arange(6), max_new_tokens=1))
        assert len(q) == 1

    def test_pop_is_global_fifo_across_buckets(self):
        q = RequestQueue(buckets=(4, 16))
        long = Request(prompt=np.arange(10), max_new_tokens=1, arrival=0.0)
        short = Request(prompt=np.arange(3), max_new_tokens=1, arrival=1.0)
        q.add(short)
        q.add(long)
        got, bucket = q.pop()
        assert got is long and bucket == 16  # earlier arrival wins
        got, bucket = q.pop()
        assert got is short and bucket == 4
        assert q.pop() is None

    def test_push_front_retries_first(self):
        q = RequestQueue(buckets=(8,))
        a = Request(prompt=np.arange(3), max_new_tokens=1, arrival=0.0)
        b = Request(prompt=np.arange(3), max_new_tokens=1, arrival=1.0)
        q.add(a)
        q.add(b)
        got, _ = q.pop()
        q.push_front(got)
        again, _ = q.pop()
        assert again is a

    def test_validation(self):
        with pytest.raises(ValueError):
            RequestQueue(buckets=())
        with pytest.raises(ValueError):
            RequestQueue(buckets=(8, 8))
        with pytest.raises(ValueError):
            Request(prompt=np.array([], np.int32), max_new_tokens=1)
        with pytest.raises(ValueError):
            Request(prompt=np.arange(3), max_new_tokens=0)

    def test_kv_accounting(self):
        r = Request(prompt=np.arange(5), max_new_tokens=3)
        assert r.prefill_len == 4  # last prompt token rides decode
        # last decode step writes position 5 + 3 - 2 = 6 -> 7 positions
        assert r.kv_tokens == 7


# ----------------------------------------------------------------- batcher
class TestContinuousBatcher:
    def test_admit_and_finish_vacates_slot(self):
        b = ContinuousBatcher(n_slots=2, max_len=16)
        r = Request(prompt=np.array([3, 1, 4]), max_new_tokens=2)
        b.admit(0, r)
        assert b.n_live == 1
        assert int(b.step[0]) == 2  # prompt_len - 1
        assert int(b.token[0]) == 4  # last prompt token
        done = b.advance(np.array([7, 0]), wall=1.0)
        assert done == [] and r.tokens == [7]
        done = b.advance(np.array([9, 0]), wall=2.0)
        assert done == [r] and r.tokens == [7, 9]
        assert b.n_live == 0 and b.free_slot() == 0
        assert r.first_token_wall == 1.0 and r.finish_wall == 2.0

    def test_slot_reuse_and_occupied_guard(self):
        b = ContinuousBatcher(n_slots=1, max_len=16)
        r1 = Request(prompt=np.array([1]), max_new_tokens=1)
        b.admit(0, r1)
        with pytest.raises(AssertionError):
            b.admit(0, Request(prompt=np.array([2]), max_new_tokens=1))
        b.advance(np.array([5]), wall=0.0)
        r2 = Request(prompt=np.array([2, 3]), max_new_tokens=1)
        b.admit(0, r2)  # vacated slot is reusable
        assert b.requests[0] is r2

    def test_fits_is_kv_aware(self):
        b = ContinuousBatcher(n_slots=1, max_len=8)
        assert b.fits(Request(prompt=np.arange(4), max_new_tokens=5))
        assert not b.fits(Request(prompt=np.arange(4), max_new_tokens=6))


# ----------------------------------------------------------------- metrics
class TestServeMetrics:
    def test_percentiles_empty_is_zero(self):
        p = percentiles([])
        assert p == {"p50": 0.0, "p99": 0.0, "mean": 0.0}

    def test_summary_counts(self):
        m = ServeMetrics()
        m.n_slots = 2
        m.record_offered(3)
        m.record_rejected(Request(prompt=np.arange(2), max_new_tokens=1), "x")
        m.record_decode_step(2)
        m.record_decode_step(1)
        m.wall_s = 1.0
        s = m.summary()
        assert s["requests"] == {
            "offered": 3, "admitted": 0, "rejected": 1, "completed": 0,
        }
        assert s["occupancy"] == pytest.approx(0.75)
        assert s["decode_steps"] == 2


# ------------------------------------------------------------ token parity
class TestPerSlotDecode:
    """[B]-step decode == per-row scalar decode at ragged depths."""

    @pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "rwkv6-7b"])
    def test_vector_steps_match_scalar_rows(self, arch):
        cfg = smoke_config(arch)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        depths = [1, 4, 7]  # ragged: each row at a different position
        max_len = 16

        @jax.jit
        def step1(tok, caches, step):
            logits, caches = model.decode_step(params, tok, caches, step)
            return logits, caches

        rows, want = [], []
        for d in depths:
            caches = model.init_cache(1, max_len, jnp.bfloat16)
            toks = rng.integers(0, cfg.vocab_size, d + 1)
            for s in range(d):  # build per-row history with scalar steps
                _, caches = step1(
                    jnp.asarray(toks[s : s + 1], jnp.int32), caches,
                    jnp.int32(s),
                )
            logits, _ = step1(
                jnp.asarray(toks[d : d + 1], jnp.int32), caches, jnp.int32(d)
            )
            rows.append((caches, toks[d]))
            want.append(np.asarray(logits[0]))

        batched = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=1), *[c for c, _ in rows]
        )
        logits, _ = model.decode_step(
            params,
            jnp.asarray([t for _, t in rows], jnp.int32),
            batched,
            jnp.asarray(depths, jnp.int32),
        )
        got = np.asarray(logits)
        np.testing.assert_allclose(got, np.stack(want), rtol=2e-2, atol=2e-2)
        # same argmax token, row for row
        np.testing.assert_array_equal(
            got.argmax(-1), np.stack(want).argmax(-1)
        )


class TestEngineParity:
    def test_served_tokens_match_unbatched_reference(self):
        """Slot recycling + bucket padding + admit masking must be
        invisible: every request's tokens equal a straight per-request
        prefill + scalar decode with the same schedule tables."""
        cfg = _moe_cfg()
        eng = ServeEngine(
            cfg, decode_slots=2, max_len=32, buckets=(4, 8),
            n_ranks=8, drop_tolerance=1.0,  # never re-plan: fixed table
            host_observe_every=10**9, seed=0,
        )
        rng = np.random.default_rng(0)
        specs = [(3, 5), (5, 4), (9, 6), (2, 5), (1, 4), (6, 3)]
        reqs = _requests(rng, cfg.vocab_size, specs)
        out = eng.run(reqs)
        assert out["serve"]["requests"]["completed"] == len(reqs)
        assert out["compile"]["decode_executables"] == 1
        assert out["compile"]["admit_executables"] == 1

        model, params = eng.model, eng.params
        dec_table = eng._ctrl.table_of(eng._state)

        @jax.jit
        def ref_step(tok, caches, step):
            logits, caches = model.decode_step(
                params, tok, caches, step, schedule=dec_table
            )
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

        prefill = jax.jit(model.prefill)
        for req in reqs:
            caches = model.init_cache(1, eng.max_len, jnp.bfloat16)
            if req.prefill_len > 0:
                _, caches = prefill(
                    params, jnp.asarray(req.prompt[None, :-1]), caches,
                    schedule=eng._prefill_table,
                )
            tok, got = int(req.prompt[-1]), []
            for s in range(req.prefill_len, req.prefill_len + req.max_new_tokens):
                nxt, caches = ref_step(
                    jnp.asarray([tok], jnp.int32), caches, jnp.int32(s)
                )
                tok = int(nxt[0])
                got.append(tok)
            assert got == req.tokens, f"request {req.rid} diverged"


# ----------------------------------------------------- admission / baseline
class TestAdmission:
    def test_kv_overflow_rejected_and_queue_waits_counted(self):
        cfg = smoke_config("h2o-danube-3-4b")  # dense: no controller
        eng = ServeEngine(
            cfg, decode_slots=1, max_len=16, buckets=(4,), seed=0
        )
        assert not eng.has_controller
        rng = np.random.default_rng(2)
        ok = _requests(rng, cfg.vocab_size, [(3, 4), (3, 4), (3, 4)])
        too_long_prompt = _requests(rng, cfg.vocab_size, [(9, 1)])  # > bucket
        too_much_kv = _requests(rng, cfg.vocab_size, [(4, 14)])  # > max_len
        out = eng.run(ok + too_long_prompt + too_much_kv)
        r = out["serve"]["requests"]
        assert r == {"offered": 5, "admitted": 3, "rejected": 2, "completed": 3}
        # one slot, simultaneous arrivals: later requests waited
        assert out["serve"]["queue_wait_steps"]["p99"] > 0
        assert out["compile"]["decode_executables"] == 1

    def test_fixed_round_baseline_still_completes(self):
        cfg = smoke_config("h2o-danube-3-4b")
        eng = ServeEngine(
            cfg, decode_slots=2, max_len=16, buckets=(4,), seed=0
        )
        rng = np.random.default_rng(3)
        reqs = _requests(rng, cfg.vocab_size, [(3, 2), (3, 6), (3, 2), (3, 6)])
        out = eng.run(reqs, continuous=False)
        assert out["serve"]["requests"]["completed"] == 4
        # drain barrier: short requests cannot backfill mid-round, so the
        # round structure shows up as strictly more decode steps than the
        # continuous lower bound (ceil(total_new_tokens / slots))
        assert out["serve"]["decode_steps"] > 8
        assert out["compile"]["decode_executables"] == 1


# ------------------------------------------------------------ regime library
class TestRegimeLibraryAPI:
    def test_requires_regime_slots(self):
        cfg = _moe_cfg()
        eng = ServeEngine(cfg, decode_slots=2, max_len=16, buckets=(4,), seed=0)
        with pytest.raises(ValueError, match="regime"):
            eng.capture_regime()
        with pytest.raises(ValueError, match="regime"):
            eng.load_regimes([np.ones((8, 8))])

    def test_load_regimes_plans_and_fills_library(self):
        cfg = _moe_cfg()
        eng = ServeEngine(
            cfg, decode_slots=2, max_len=16, buckets=(4,),
            regime_slots=2, seed=0,
        )
        ref = np.ones((8, 8), np.float32)
        np.fill_diagonal(ref, 0.0)
        eng.load_regimes([ref])
        m = eng.metrics()["controller"]
        assert m["regime_library_size"] == 1
        assert m["regime_warm_swaps"] == 0
        with pytest.raises(ValueError, match="shape"):
            eng.load_regimes([np.ones((4, 4))])


# -------------------------------------------------------- drifting e2e smoke
# Token pools probed offline against the PRNGKey(0)-initialized
# mixtral-8x7b smoke router: pool A's tokens route (top-2) into experts
# {6, 7}, pool B's avoid them entirely, so the two request mixes realize
# disjoint-column traffic regimes on the 8-rank fabric.
_POOL_A = np.array([5, 7, 8, 17, 21, 23, 33, 36, 42, 43, 44, 53])
_POOL_B = np.array([1, 11, 22, 27, 29, 37, 41, 56, 67, 72, 75, 78])

_DRIFT_CACHE: dict = {}


def _drift_run():
    """One A -> capture -> B -> A2 serving run, shared by the e2e asserts
    (the engine compiles once; re-running per test would dominate the
    suite's wall clock)."""
    if _DRIFT_CACHE:
        return _DRIFT_CACHE
    cfg = _moe_cfg()
    eng = ServeEngine(
        cfg, decode_slots=32, max_len=64, buckets=(16,), n_ranks=8,
        regime_slots=4, regime_threshold=0.3, drop_tolerance=0.01,
        hysteresis_steps=1, cooldown=2, ema=0.8, host_observe_every=14,
        # smoke-scale decode traffic needs finer solver caps than the
        # training-scale defaults for drift to register at all
        plan_overrides=dict(quantum=1, min_cap=1, slack=1.0), seed=0,
    )
    rng = np.random.default_rng(3)

    def phase(pool):
        return _requests(rng, cfg.vocab_size, [(12, 14)] * 64, pool=pool)

    snap = {}
    for name, pool in [("A", _POOL_A), ("B", _POOL_B), ("A2", _POOL_A)]:
        eng.run(phase(pool))
        m = eng.metrics()
        snap[name] = {
            "replans": m["controller"]["device_replans"],
            "warm": m["controller"]["regime_warm_swaps"],
            "lib": m["controller"]["regime_library_size"],
            "compile": dict(m["compile"]),
            "completed": m["serve"]["requests"]["completed"],
        }
        if name == "A":
            eng.capture_regime()
    _DRIFT_CACHE["snap"] = snap
    _DRIFT_CACHE["engine"] = eng
    return _DRIFT_CACHE


class TestDriftE2E:
    def test_regimes_drive_cold_then_warm_replans(self):
        snap = _drift_run()["snap"]
        # A ramps against the uniform-primed plan: cold re-plans fire
        assert snap["A"]["replans"] >= 1
        assert snap["A"]["warm"] == 0  # library still empty
        # B is a regime MISS (disjoint experts): cold solve, no warm hit
        assert snap["B"]["replans"] > snap["A"]["replans"]
        assert snap["B"]["warm"] == 0
        assert snap["B"]["lib"] == 1  # A was captured
        # A2 returns to the captured regime: the re-plan is a warm swap
        assert snap["A2"]["warm"] >= 1
        assert snap["A2"]["replans"] > snap["B"]["replans"]

    def test_zero_recompiles_across_drift_and_recycling(self):
        snap = _drift_run()["snap"]
        for name in ("A", "B", "A2"):
            c = snap[name]["compile"]
            assert c["decode_executables"] == 1, (name, c)
            assert c["prefill_executables"] == 1, (name, c)
            assert c["admit_executables"] == 1, (name, c)
        assert snap["A2"]["completed"] == 3 * 64

    def test_warm_swap_replays_library_table_verbatim(self):
        run = _drift_run()
        snap, eng = run["snap"], run["engine"]
        # every A2 re-plan was a warm swap, so the live state's plan IS
        # the captured library entry, bit for bit
        assert (
            snap["A2"]["replans"] - snap["B"]["replans"]
            == snap["A2"]["warm"] - snap["B"]["warm"]
        )
        bank = eng._bank_tables[0]
        st = eng._state
        np.testing.assert_array_equal(np.asarray(st.perms), bank.perms)
        np.testing.assert_array_equal(np.asarray(st.caps), bank.caps)
        np.testing.assert_array_equal(np.asarray(st.valid), bank.valid)
        np.testing.assert_array_equal(
            np.asarray(st.n_phases), bank.n_phases
        )
