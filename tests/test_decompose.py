"""Unit + property tests for the decomposition algorithms (paper §3)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic in-repo sweep
    from _hyp_compat import given, settings
    from _hyp_compat import strategies as st

from repro.core import (
    bvn_coefficients,
    bvn_decompose,
    decompose,
    ideal_a2a_tokens,
    is_doubly_stochastic,
    maxweight_decompose,
    ring_a2a_tokens,
    sinkhorn,
)


def _rand_traffic(rng, n=8, density=0.6, scale=1000.0):
    m = rng.random((n, n)) * scale
    mask = rng.random((n, n)) < density
    m = m * mask
    np.fill_diagonal(m, 0.0)
    return np.floor(m)


# ---------------------------------------------------------------- sinkhorn
class TestSinkhorn:
    def test_doubly_stochastic_output(self):
        rng = np.random.default_rng(0)
        for _ in range(5):
            m = _rand_traffic(rng)
            s = sinkhorn(m)
            assert is_doubly_stochastic(s)

    def test_preserves_zero_pattern_up_to_eps(self):
        rng = np.random.default_rng(1)
        m = _rand_traffic(rng, density=0.4)
        s = sinkhorn(m)
        # zero entries only get the epsilon regularization mass
        zeros = (m == 0) & ~np.eye(8, dtype=bool)
        assert s[zeros].max() < 1e-3

    def test_rejects_negative(self):
        with pytest.raises(ValueError):
            sinkhorn(np.array([[1.0, -1.0], [1.0, 1.0]]))

    @given(st.integers(min_value=2, max_value=12), st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_property_always_bistochastic(self, n, seed):
        rng = np.random.default_rng(seed)
        m = rng.random((n, n)) * (rng.random((n, n)) < 0.7)
        s = sinkhorn(m)
        assert is_doubly_stochastic(s)


# --------------------------------------------------------------------- BvN
class TestBvN:
    def test_reconstructs_doubly_stochastic(self):
        rng = np.random.default_rng(2)
        s = sinkhorn(_rand_traffic(rng))
        coeffs = bvn_coefficients(s, tol=1e-9)
        recon = np.zeros_like(s)
        n = s.shape[0]
        for lam, perm in coeffs:
            recon[np.arange(n), perm] += lam
        assert np.allclose(recon, s, atol=1e-6)

    def test_marcus_ree_bound(self):
        rng = np.random.default_rng(3)
        for _ in range(5):
            s = sinkhorn(_rand_traffic(rng))
            coeffs = bvn_coefficients(s, tol=1e-9)
            n = s.shape[0]
            assert len(coeffs) <= (n - 1) ** 2 + 1

    def test_full_pipeline_delivers_demand(self):
        rng = np.random.default_rng(4)
        m = _rand_traffic(rng)
        d = bvn_decompose(m)
        d.verify()

    def test_bottleneck_fewer_or_equal_matchings(self):
        rng = np.random.default_rng(5)
        m = _rand_traffic(rng)
        plain = bvn_decompose(m)
        bneck = bvn_decompose(m, bottleneck=True)
        bneck.verify()
        assert bneck.meta["num_bvn_matchings"] <= plain.meta["num_bvn_matchings"] + 2

    def test_paper_claim_many_small_matchings_on_skewed_traffic(self):
        """§4.2: BvN produces many matchings with tiny coefficients on
        skewed MoE traffic (paper: up to 50 for n=8, coeffs ~0.03)."""
        rng = np.random.default_rng(6)
        n = 8
        # Heavy-tailed skew: a few dominant pairs + noise.
        m = np.floor(rng.random((n, n)) * 30)
        m[0, 1] = 4000
        m[2, 3] = 3500
        m[5, 6] = 2800
        np.fill_diagonal(m, 0)
        d = bvn_decompose(m)
        coeffs = d.meta["coefficients"]
        assert len(coeffs) > 12  # fragmented
        assert min(coeffs) < 0.05  # tiny matchings exist


# -------------------------------------------------------------- max-weight
class TestMaxWeight:
    def test_delivers_demand_exactly(self):
        rng = np.random.default_rng(7)
        for _ in range(5):
            m = _rand_traffic(rng)
            d = maxweight_decompose(m)
            d.verify()

    def test_On_matchings(self):
        """Paper §3.2/Fig 2: MW bounds matchings to O(n) (vs O(n^2) BvN)."""
        rng = np.random.default_rng(8)
        for _ in range(10):
            m = _rand_traffic(rng, density=1.0)  # fully dense worst case
            d = maxweight_decompose(m)
            assert d.num_phases <= m.shape[0] + 2

    def test_alloc_equals_sent_no_bubbles(self):
        rng = np.random.default_rng(9)
        m = _rand_traffic(rng)
        d = maxweight_decompose(m)
        for p in d.phases:
            np.testing.assert_allclose(p.alloc, p.sent)

    def test_first_matching_contains_max_entry(self):
        rng = np.random.default_rng(10)
        m = _rand_traffic(rng)
        d = maxweight_decompose(m)
        assert d.phases[0].sent.max() == m.max()

    def test_descending_phase_weight(self):
        rng = np.random.default_rng(11)
        m = _rand_traffic(rng)
        d = maxweight_decompose(m)
        weights = [p.sent.sum() for p in d.phases]
        assert all(weights[i] >= weights[i + 1] - 1e-9 for i in range(len(weights) - 1))

    @given(
        st.integers(min_value=2, max_value=10),
        st.integers(min_value=0, max_value=2**31 - 1),
        st.floats(min_value=0.1, max_value=1.0),
    )
    @settings(max_examples=30, deadline=None)
    def test_property_exact_delivery_and_On(self, n, seed, density):
        rng = np.random.default_rng(seed)
        m = np.floor(rng.random((n, n)) * 100 * (rng.random((n, n)) < density))
        np.fill_diagonal(m, 0.0)
        d = maxweight_decompose(m)
        d.verify()
        # each phase clears all selected entries: nnz shrinks by >= 1/phase,
        # and by ~n for dense rounds => never more than nnz phases
        assert d.num_phases <= max(int((m > 0).sum()), 1)


# ------------------------------------------------------------- decompose()
class TestDecomposeAPI:
    @pytest.mark.parametrize("strategy", ["bvn", "bvn-bottleneck", "maxweight", "shift"])
    def test_all_strategies_deliver(self, strategy):
        rng = np.random.default_rng(12)
        m = _rand_traffic(rng)
        np.fill_diagonal(m, 17.0)  # local traffic present
        d = decompose(m, strategy)
        off = m.copy()
        np.fill_diagonal(off, 0.0)
        np.testing.assert_allclose(d.sent_total(), off, atol=1e-6)
        np.testing.assert_allclose(d.meta["local_tokens"], np.full(8, 17.0))

    def test_unknown_strategy_raises(self):
        with pytest.raises(ValueError):
            decompose(np.eye(4), "nope")


# --------------------------------------------------------------- baselines
class TestBaselines:
    def test_ideal_bound(self):
        m = np.array([[0.0, 10.0], [4.0, 0.0]])
        assert ideal_a2a_tokens(m) == 10.0

    def test_ring_at_least_ideal(self):
        rng = np.random.default_rng(13)
        for _ in range(5):
            m = _rand_traffic(rng, n=6)
            assert ring_a2a_tokens(m) >= ideal_a2a_tokens(m) - 1e-6

    def test_ring_uniform_known_value(self):
        # n=4 uniform demand v: each node sends v to 3 others; opposite
        # node traffic (distance 2) splits across directions.  LP optimum
        # equals max link load = 2v (neighbor v + half of 2 distance-2
        # demands each way); NIC-normalized time doubles it.
        n, v = 4, 12.0
        m = np.full((n, n), v)
        np.fill_diagonal(m, 0.0)
        assert abs(ring_a2a_tokens(m, normalize_nic=False) - 2 * v) < 1e-6
        assert abs(ring_a2a_tokens(m) - 4 * v) < 1e-6

    def test_ring_single_demand_splits(self):
        # One demand between adjacent nodes: the LP splits it across both
        # (half-rate) directions -> same time as a full-rate direct link.
        n = 8
        m = np.zeros((n, n))
        m[0, 1] = 100.0
        assert abs(ring_a2a_tokens(m) - 100.0) < 1e-6


# ------------------------------------------------------------- hierarchical
class TestHierarchical:
    def _two_pod_traffic(self, seed=0, n=16, pod=8, locality=0.8):
        rng = np.random.default_rng(seed)
        m = np.floor(rng.random((n, n)) * 200)
        for i in range(n):
            for j in range(n):
                if (i // pod) != (j // pod):
                    m[i, j] = np.floor(m[i, j] * (1 - locality))
        np.fill_diagonal(m, 0.0)
        return m

    def test_split_partitions_traffic(self):
        from repro.core.hierarchical import split_traffic

        m = self._two_pod_traffic()
        intra, inter = split_traffic(m, 8)
        np.testing.assert_allclose(intra + inter, m)
        assert inter[:8, :8].sum() == 0 and intra[:8, 8:].sum() == 0

    def test_hierarchical_delivers_everything(self):
        from repro.core.hierarchical import hierarchical_decompose

        m = self._two_pod_traffic(seed=1)
        intra_d, inter_d = hierarchical_decompose(m, 8)
        intra_d.verify()
        inter_d.verify()
        total = intra_d.sent_total() + inter_d.sent_total()
        np.testing.assert_allclose(total, m, atol=1e-6)

    def test_hierarchical_beats_flat_on_local_traffic(self):
        """With slow inter-pod links and local-heavy traffic, pod-aware
        scheduling must win (beyond-paper claim, DESIGN.md §2.3)."""
        from repro.core import CommModel, linear_model
        from repro.core.hierarchical import simulate_hierarchical

        wins = 0
        for seed in range(5):
            m = self._two_pod_traffic(seed=seed, locality=0.9)
            res = simulate_hierarchical(
                m,
                8,
                linear_model(per_token_us=0.05),
                CommModel(tokens_per_us=100.0),   # fast ICI
                CommModel(tokens_per_us=10.0),    # 10x slower DCI
            )
            if res["speedup"] > 1.0:
                wins += 1
        assert wins >= 4, wins
