"""Online schedule selection under routing drift (core/selector.py)."""

import numpy as np

from repro.core.schedule import ring_schedule
from repro.core.selector import ScheduleEntry, ScheduleSelector
from repro.core.traffic import RouterConfig, traffic_matrix


def _traffic(seed, alpha=0.3, n=8, tpr=2048):
    rng = np.random.default_rng(seed)
    r = RouterConfig("t", 16, 2)
    return traffic_matrix(rng, r, np.full(n, tpr), n_ranks=n, skew_alpha=alpha)


class TestScheduleSelector:
    def test_first_observation_plans(self):
        sel = ScheduleSelector(8)
        entry, changed = sel.observe(_traffic(0))
        assert changed and sel.replans == 1
        assert entry.schedule.num_phases >= 1

    def test_stable_traffic_keeps_schedule(self):
        sel = ScheduleSelector(8)
        sel.observe(_traffic(0))
        for seed in range(1, 6):  # same distributional regime
            _, changed = sel.observe(_traffic(0) * (1 + 0.02 * seed))
            assert not changed
        assert sel.replans == 1 and sel.switches == 0

    def test_drift_triggers_replan(self):
        sel = ScheduleSelector(8, ema=1.0)  # react immediately (test)
        sel.observe(_traffic(0))
        # a very different regime: rotate the heavy pairs
        drifted = np.roll(_traffic(0), 3, axis=1)
        np.fill_diagonal(drifted, 0.0)
        entry, changed = sel.observe(drifted)
        assert changed
        assert sel.replans == 2
        # and the new schedule serves the drifted traffic losslessly-ish
        assert entry.drop_fraction(drifted) <= sel.drop_tolerance + 1e-9

    def test_returning_regime_reuses_library(self):
        sel = ScheduleSelector(8, ema=1.0)
        a = _traffic(0)
        b = np.roll(a, 3, axis=1)
        np.fill_diagonal(b, 0.0)
        sel.observe(a)
        sel.observe(b)
        replans = sel.replans
        entry, changed = sel.observe(a)  # regime A returns
        assert changed
        assert sel.replans == replans, "should reuse the library, not replan"


def _uniform_entry(name, n, cap, traffic_scale=1.0):
    """Entry whose cap matrix is uniformly ``cap`` on off-diag pairs."""
    sched = ring_schedule(n, cap)
    ref = np.full((n, n), traffic_scale)
    np.fill_diagonal(ref, 0.0)
    return ScheduleEntry(name=name, reference=ref, schedule=sched)


class TestHysteresis:
    """Switching away from current requires a relative drop improvement."""

    def _selector(self, hysteresis):
        n = 4
        sel = ScheduleSelector(
            n, ema=1.0, drop_tolerance=0.06, hysteresis=hysteresis
        )
        a = _uniform_entry("a", n, cap=90)  # drop 0.10 on 100/pair traffic
        b = _uniform_entry("b", n, cap=94)  # drop 0.06 on 100/pair traffic
        sel.library = [a, b]
        sel.current = a
        traffic = np.full((n, n), 100.0)
        np.fill_diagonal(traffic, 0.0)
        return sel, a, b, traffic

    def test_small_improvement_rides_current(self):
        sel, a, b, traffic = self._selector(hysteresis=0.5)
        p = sel.propose(traffic)  # b improves 0.10 -> 0.06: only 40% < 50%
        assert p.action == "keep" and p.entry is a

    def test_zero_hysteresis_switches(self):
        sel, a, b, traffic = self._selector(hysteresis=0.0)
        p = sel.propose(traffic)
        assert p.action == "switch" and p.entry is b


class TestCooldown:
    def test_cooldown_suppresses_replans(self):
        sel = ScheduleSelector(8, ema=1.0, cooldown=5)
        a = _traffic(0)
        b = np.roll(a, 3, axis=1)
        np.fill_diagonal(b, 0.0)
        sel.observe(a)
        assert sel.replans == 1
        for _ in range(5):  # inside the cooldown window: no re-plan storm
            entry, _ = sel.observe(b)
            assert sel.replans == 1
        sel.observe(b)  # window elapsed: the miss is allowed through
        assert sel.replans == 2

    def test_cooldown_still_allows_library_switches(self):
        n = 4
        sel = ScheduleSelector(n, ema=1.0, drop_tolerance=0.06, cooldown=100)
        a = _uniform_entry("a", n, cap=40)  # drop 0.60
        b = _uniform_entry("b", n, cap=94)  # drop 0.06
        sel.library = [a, b]
        sel.current = a
        sel._cooldown_left = 100
        traffic = np.full((n, n), 100.0)
        np.fill_diagonal(traffic, 0.0)
        p = sel.propose(traffic)
        assert p.action == "switch" and p.entry is b


class TestReplanPenalty:
    """'To reconfigure, or not': a swap's dark window must pay for itself."""

    def test_comm_model_penalty_units(self):
        import pytest

        from repro.core import CommModel

        m = CommModel(
            tokens_per_us=100.0, reconf_us=0.01, replan_dark_us=10.0
        )
        # 10 us dark x 100 tok/us = 1000 tokens blacked out; over a
        # 4000-token observation window that is a 0.25 drop-equivalent
        assert m.replan_penalty(4000.0) == pytest.approx(0.25)
        assert m.replan_penalty(0.0) == 0.0  # degenerate window
        legacy = CommModel(tokens_per_us=100.0, reconf_us=0.01)
        assert legacy.replan_penalty(4000.0) == 0.0  # dark window off
        hw = CommModel.from_hardware(replan_dark_us=7.0)
        assert hw.replan_dark_us == 7.0

    def _pressured(self, penalty, n=4):
        """Current plan 10% over a 2% tolerance: drop pressure is real,
        but a fresh plan can save at most that 0.10."""
        sel = ScheduleSelector(
            n, ema=1.0, drop_tolerance=0.02, replan_penalty=penalty
        )
        a = _uniform_entry("a", n, cap=90)  # drop 0.10 on 100/pair
        sel.library = [a]
        sel.current = a
        traffic = np.full((n, n), 100.0)
        np.fill_diagonal(traffic, 0.0)
        return sel, a, traffic

    def test_penalty_declines_fresh_plan_for_small_drop(self):
        sel, a, traffic = self._pressured(penalty=0.25)
        p = sel.propose(traffic)  # saving 0.10 < dark window 0.25
        assert p.action == "keep" and p.entry is a

    def test_zero_penalty_keeps_legacy_miss(self):
        sel, _, traffic = self._pressured(penalty=0.0)
        assert sel.propose(traffic).action == "miss"

    def test_library_switch_requires_saving_above_penalty(self):
        n = 4
        traffic = np.full((n, n), 100.0)
        np.fill_diagonal(traffic, 0.0)
        for penalty, action in [(0.05, "keep"), (0.03, "switch")]:
            sel = ScheduleSelector(
                n, ema=1.0, drop_tolerance=0.06, replan_penalty=penalty
            )
            a = _uniform_entry("a", n, cap=90)  # drop 0.10
            b = _uniform_entry("b", n, cap=94)  # drop 0.06: saves 0.04
            sel.library = [a, b]
            sel.current = a
            assert sel.propose(traffic).action == action

    def test_negative_penalty_rejected(self):
        import pytest

        with pytest.raises(ValueError, match="replan_penalty"):
            ScheduleSelector(4, replan_penalty=-0.1)
