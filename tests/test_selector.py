"""Online schedule selection under routing drift (core/selector.py)."""

import numpy as np

from repro.core.selector import ScheduleSelector
from repro.core.traffic import RouterConfig, traffic_matrix


def _traffic(seed, alpha=0.3, n=8, tpr=2048):
    rng = np.random.default_rng(seed)
    r = RouterConfig("t", 16, 2)
    return traffic_matrix(rng, r, np.full(n, tpr), n_ranks=n, skew_alpha=alpha)


class TestScheduleSelector:
    def test_first_observation_plans(self):
        sel = ScheduleSelector(8)
        entry, changed = sel.observe(_traffic(0))
        assert changed and sel.replans == 1
        assert entry.schedule.num_phases >= 1

    def test_stable_traffic_keeps_schedule(self):
        sel = ScheduleSelector(8)
        sel.observe(_traffic(0))
        for seed in range(1, 6):  # same distributional regime
            _, changed = sel.observe(_traffic(0) * (1 + 0.02 * seed))
            assert not changed
        assert sel.replans == 1 and sel.switches == 0

    def test_drift_triggers_replan(self):
        sel = ScheduleSelector(8, ema=1.0)  # react immediately (test)
        sel.observe(_traffic(0))
        # a very different regime: rotate the heavy pairs
        drifted = np.roll(_traffic(0), 3, axis=1)
        np.fill_diagonal(drifted, 0.0)
        entry, changed = sel.observe(drifted)
        assert changed
        assert sel.replans == 2
        # and the new schedule serves the drifted traffic losslessly-ish
        assert entry.drop_fraction(drifted) <= sel.drop_tolerance + 1e-9

    def test_returning_regime_reuses_library(self):
        sel = ScheduleSelector(8, ema=1.0)
        a = _traffic(0)
        b = np.roll(a, 3, axis=1)
        np.fill_diagonal(b, 0.0)
        sel.observe(a)
        sel.observe(b)
        replans = sel.replans
        entry, changed = sel.observe(a)  # regime A returns
        assert changed
        assert sel.replans == replans, "should reuse the library, not replan"
