"""End-to-end behaviour tests for the paper's system.

Ties the layers together: trace -> decomposition -> simulator (the paper's
claim chain), and the framework's plan -> train -> checkpoint -> resume
loop on a small MoE model.
"""

import numpy as np

from repro.core import (
    CommModel,
    decompose,
    gen_trace,
    knee_model,
    plan_schedule,
    simulate_decomposition,
    simulate_sequential,
)


def test_end_to_end_paper_pipeline():
    """Trace -> BvN/MW -> simulate: MW+overlap must beat BvN+overlap on
    large batches, and every decomposition must deliver all traffic."""
    comm = CommModel.from_hardware(link_gbps=400, d_model=6144)
    knee = knee_model()
    mats = gen_trace("mixtral-8x22b", "speed", iterations=6, seed=0)
    mw_wins = 0
    for m in mats:
        res = {}
        for strat in ("bvn", "maxweight"):
            d = decompose(m, strat)
            d.verify()
            res[strat] = simulate_decomposition(
                d, knee, comm, local_tokens=d.meta["local_tokens"]
            ).makespan_us
        ring = simulate_sequential(m, knee, comm).makespan_us
        assert res["maxweight"] < ring  # large batch: decomposition helps
        if res["maxweight"] <= res["bvn"]:
            mw_wins += 1
    assert mw_wins >= 4, f"MW won only {mw_wins}/6 vs BvN"


def test_plan_schedule_executable_invariants():
    """Planned schedules obey the runtime contract: valid pairs unique,
    capacities cover the planned traffic up to quantile drops."""
    mats = gen_trace("dbrx", "speed", iterations=3, seed=1, n_ranks=16)
    for m in mats:
        d = decompose(m, "maxweight", min_fill=0.1)
        s = plan_schedule(d, slack=1.0, quantum=8)
        s.validate()
        # lossless plan: every off-diagonal token has a slot
        off = m.copy()
        np.fill_diagonal(off, 0)
        rem = off.copy()
        idx = np.arange(s.n)
        for k in range(s.num_phases):
            sel = s.valid[k]
            vols = rem[idx[sel], s.perms[k][sel]]
            rem[idx[sel], s.perms[k][sel]] = np.maximum(vols - int(s.caps[k]), 0)
        assert rem.sum() / off.sum() < 1e-9


def test_train_checkpoint_resume_roundtrip(tmp_path):
    """Short training run improves loss; a resumed run continues from the
    checkpoint (single device, ~30s)."""
    from repro.configs.base import ModelConfig, MoECfg
    from repro.data import DataConfig
    from repro.models import Model
    from repro.train import TrainLoopConfig, train_loop

    cfg = ModelConfig(
        name="sys-test",
        family="moe",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=128,
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=32),
        remat="none",
    )
    model = Model(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    loop_cfg = TrainLoopConfig(
        steps=30, ckpt_dir=str(tmp_path), ckpt_every=10, peak_lr=5e-3,
        warmup=5, log_every=5,
    )
    res = train_loop(model, data_cfg, loop_cfg)
    losses = [h["loss"] for h in res["history"]]
    assert losses[-1] < losses[0], losses

    # resume: extends to 40 steps from the saved step-30 checkpoint
    loop_cfg2 = TrainLoopConfig(
        steps=40, ckpt_dir=str(tmp_path), ckpt_every=10, peak_lr=5e-3,
        warmup=5, log_every=5,
    )
    res2 = train_loop(model, data_cfg, loop_cfg2)
    assert res2["final_step"] == 40
    assert np.isfinite(res2["final_loss"])
