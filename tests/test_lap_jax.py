"""Device-resident solver + controller tests (PR 7).

Property-tests the batched JAX auction LAP against the scipy
Jonker-Volgenant oracle (exact weight equality on integer matrices —
the module's headline contract), the traced greedy-phases planner
against per-phase LAP optimality on its own residual, the traced
link-mask/routing folds against their host twins, and the in-graph
observe -> score -> re-plan loop of ``DeviceController`` (hysteresis,
cooldown, masked re-plans, and the zero-recompile carry).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.optimize import linear_sum_assignment

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fall back to the deterministic in-repo sweep
    from _hyp_compat import given, settings
    from _hyp_compat import strategies as st

from repro.core import (
    ControllerConfig,
    DeviceController,
    ScheduleRuntime,
    apply_link_mask,
    apply_link_mask_traced,
    auction_lap,
    auction_lap_batch,
    decompose_batch,
    greedy_phases_jax,
    matching_weight,
    routing_to_traffic,
    routing_to_traffic_traced,
)

N = 4  # fabric size of the controller tests (virtual ranks)
E = 8  # experts


def _int_matrix(rng, n, hi=1000):
    return rng.integers(0, hi, size=(n, n)).astype(np.float64)


def _scipy_weight(a, maximize=True):
    r, c = linear_sum_assignment(a, maximize=maximize)
    return float(np.asarray(a)[r, c].sum())


def _is_permutation(perm, n):
    return sorted(int(v) for v in np.asarray(perm)) == list(range(n))


# ------------------------------------------------------------- auction LAP
class TestAuctionLap:
    @given(
        st.integers(min_value=2, max_value=12),
        st.integers(min_value=0, max_value=2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_property_permutation_and_scipy_weight(self, n, seed):
        """Integer matrices: valid permutation, weight == scipy exactly."""
        rng = np.random.default_rng(seed)
        a = _int_matrix(rng, n)
        perm = np.asarray(auction_lap(a))
        assert _is_permutation(perm, n)
        got = float(a[np.arange(n), perm].sum())
        assert got == _scipy_weight(a)

    def test_ties_stay_weight_optimal(self):
        """Heavily tied matrices: ties may break differently from scipy,
        but the matching weight must still be the optimum."""
        rng = np.random.default_rng(7)
        for _ in range(10):
            n = int(rng.integers(2, 10))
            a = rng.choice([0.0, 10.0, 20.0], size=(n, n))
            perm = np.asarray(auction_lap(a))
            assert _is_permutation(perm, n)
            assert float(a[np.arange(n), perm].sum()) == _scipy_weight(a)

    def test_minimize_matches_scipy(self):
        rng = np.random.default_rng(11)
        a = _int_matrix(rng, 8)
        perm = np.asarray(auction_lap(a, maximize=False))
        assert _is_permutation(perm, 8)
        got = float(a[np.arange(8), perm].sum())
        assert got == _scipy_weight(a, maximize=False)

    def test_float_matrices_within_subtoken_gap(self):
        """Arbitrary floats (EMA'd traffic): epsilon-optimal, gap < 1."""
        rng = np.random.default_rng(13)
        for _ in range(5):
            a = rng.random((10, 10)) * 500.0
            perm = np.asarray(auction_lap(a))
            got = float(a[np.arange(10), perm].sum())
            opt = _scipy_weight(a)
            assert opt - 1.0 <= got <= opt + 1e-3

    def test_link_mask_matches_scipy_on_penalized_matrix(self):
        """Masked solves are the same LAP instance scipy would see with
        dark pairs driven to the module's -big penalty: equal weight, and
        dark pairs only used when a row has no usable column left."""
        rng = np.random.default_rng(17)
        for _ in range(8):
            n = int(rng.integers(3, 10))
            a = _int_matrix(rng, n, hi=300)
            mask = rng.random((n, n)) < 0.7
            # keep one full permutation usable so darks are avoidable
            keep = rng.permutation(n)
            mask[np.arange(n), keep] = True
            perm = np.asarray(auction_lap(a, mask))
            assert _is_permutation(perm, n)
            assert mask[np.arange(n), perm].all()
            big = (np.abs(a).max() + 1.0) * (n + 1)
            pen = np.where(mask, a, -big)
            got = float(pen[np.arange(n), perm].sum())
            assert got == _scipy_weight(pen)

    def test_rejects_non_square(self):
        with pytest.raises(ValueError, match="square"):
            auction_lap(np.zeros((3, 4)))


class TestAuctionLapBatch:
    @given(
        st.integers(min_value=2, max_value=8),
        st.integers(min_value=0, max_value=2**31 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_vmapped_parity_per_layer(self, n, seed):
        """Every layer of the vmapped solve matches its own scipy solve."""
        rng = np.random.default_rng(seed)
        stack = np.stack([_int_matrix(rng, n) for _ in range(4)])
        perms = np.asarray(auction_lap_batch(stack))
        assert perms.shape == (4, n)
        for l in range(4):
            assert _is_permutation(perms[l], n)
            got = float(stack[l][np.arange(n), perms[l]].sum())
            assert got == _scipy_weight(stack[l])

    def test_shared_mask_applies_to_every_layer(self):
        rng = np.random.default_rng(23)
        n = 6
        stack = np.stack([_int_matrix(rng, n, hi=200) for _ in range(3)])
        mask = np.ones((n, n), bool)
        mask[0, 1] = mask[3, 4] = False
        keep = rng.permutation(n)
        mask[np.arange(n), keep] = True
        perms = np.asarray(auction_lap_batch(stack, mask))
        for l in range(3):
            assert mask[np.arange(n), perms[l]].all()
            big = (np.abs(stack).max() + 1.0) * (n + 1)
            pen = np.where(mask, stack[l], -big)
            got = float(pen[np.arange(n), perms[l]].sum())
            assert got == _scipy_weight(pen)

    def test_rejects_wrong_rank(self):
        with pytest.raises(ValueError, match=r"\[L, n, n\]"):
            auction_lap_batch(np.zeros((4, 4)))


class TestMatchingWeight:
    def test_known_value_and_batching(self):
        a = np.arange(9, dtype=np.float64).reshape(3, 3)
        perm = np.array([2, 0, 1])
        assert float(matching_weight(a, perm)) == a[0, 2] + a[1, 0] + a[2, 1]
        stack = np.stack([a, 2 * a])
        w = np.asarray(matching_weight(stack, np.stack([perm, perm])))
        np.testing.assert_allclose(w, [12.0, 24.0])


# --------------------------------------------------------- traced planner
class TestGreedyPhasesJax:
    def _traffic(self, rng, L=3, n=6, hi=400):
        a = rng.integers(0, hi, size=(L, n, n)).astype(np.float64)
        for l in range(L):
            np.fill_diagonal(a[l], 0.0)
        return a

    def test_table_leaf_shapes_and_dtypes(self):
        rng = np.random.default_rng(3)
        a = self._traffic(rng)
        L, n = a.shape[0], a.shape[1]
        k = n
        plan = greedy_phases_jax(a, k_max=k)
        assert plan["perms"].shape == (L, k, n)
        assert plan["perms"].dtype == jnp.int32
        assert plan["caps"].shape == (L, k)
        assert plan["caps"].dtype == jnp.int32
        assert plan["valid"].shape == (L, k, n)
        assert plan["n_phases"].shape == (L,)
        # live slots form a prefix; dark slots carry identity perms, cap 0
        valid = np.asarray(plan["valid"])
        live = valid.any(axis=2)
        for l in range(L):
            nl = int(plan["n_phases"][l])
            assert live[l, :nl].all() and not live[l, nl:].any()
            np.testing.assert_array_equal(
                np.asarray(plan["perms"])[l, nl:],
                np.broadcast_to(np.arange(n), (k - nl, n)),
            )
            assert not np.asarray(plan["caps"])[l, nl:].any()

    def test_each_phase_is_lap_optimal_on_its_own_residual(self):
        """Slot k's matching is a scipy-optimal LAP solve of the residual
        the jax path itself carried into slot k (min_fill=0 greedy)."""
        rng = np.random.default_rng(5)
        a = self._traffic(rng)
        L, n = a.shape[0], a.shape[1]
        plan = greedy_phases_jax(a, k_max=n)
        perms = np.asarray(plan["perms"])
        valid = np.asarray(plan["valid"])
        sent = np.asarray(plan["sent"])
        for l in range(L):
            resid = a[l].copy()
            for k in range(n):
                # unpenalized, like the host greedy: diagonal entries are
                # zero in the residual, so parking on them is free
                got = float(resid[np.arange(n), perms[l, k]].sum())
                assert got == _scipy_weight(resid), (l, k)
                # sent is the residual at the matched usable pairs
                np.testing.assert_array_equal(
                    sent[l, k],
                    np.where(valid[l, k], resid[np.arange(n), perms[l, k]], 0.0),
                )
                resid[np.arange(n)[valid[l, k]], perms[l, k][valid[l, k]]] = 0.0

    def test_conservation_and_full_admission(self):
        """sent + residual == traffic; k_max = n clears every matrix."""
        rng = np.random.default_rng(9)
        a = self._traffic(rng)
        plan = greedy_phases_jax(a, k_max=a.shape[1])
        sent_total = np.asarray(plan["sent"]).sum()
        resid = np.asarray(plan["residual"])
        np.testing.assert_allclose(sent_total + resid.sum(), a.sum())
        np.testing.assert_allclose(resid, 0.0)

    def test_caps_follow_plan_schedule_rounding(self):
        rng = np.random.default_rng(15)
        a = self._traffic(rng)
        q, mc, slack = 8, 8, 1.1
        plan = greedy_phases_jax(
            a, k_max=a.shape[1], quantum=q, min_cap=mc, slack=slack
        )
        sent = np.asarray(plan["sent"])
        valid = np.asarray(plan["valid"])
        caps = np.asarray(plan["caps"])
        for l in range(a.shape[0]):
            for k in range(a.shape[1]):
                if not valid[l, k].any():
                    assert caps[l, k] == 0
                    continue
                want = max(int(np.ceil(sent[l, k].max() * slack)), mc)
                want = -(-want // q) * q
                assert caps[l, k] == want

    def test_masked_pairs_never_valid(self):
        rng = np.random.default_rng(21)
        a = self._traffic(rng)
        n = a.shape[1]
        mask = np.ones((n, n), bool)
        mask[0, 1] = mask[2, 5] = mask[4, 0] = False
        plan = greedy_phases_jax(a, k_max=n, mask=mask)
        perms = np.asarray(plan["perms"])
        valid = np.asarray(plan["valid"])
        for l in range(a.shape[0]):
            for k in range(n):
                on = valid[l, k]
                assert mask[np.arange(n)[on], perms[l, k][on]].all()

    def test_k_max_clip_leaves_planned_drops(self):
        rng = np.random.default_rng(27)
        a = self._traffic(rng, L=2, n=8)
        plan = greedy_phases_jax(a, k_max=2)
        assert np.asarray(plan["residual"]).sum() > 0
        assert int(np.asarray(plan["n_phases"]).max()) == 2


class TestDecomposeBatchJaxBackend:
    def _unique_stack(self, rng, L=3, n=6):
        """Distinct integer entries -> generically unique optima, so the
        two backends' greedy paths coincide phase for phase."""
        vals = rng.choice(100_000, size=L * n * n, replace=False)
        a = vals.reshape(L, n, n).astype(np.float64)
        for l in range(L):
            np.fill_diagonal(a[l], 0.0)
        return a

    def test_jax_backend_matches_scipy_path(self):
        rng = np.random.default_rng(31)
        a = self._unique_stack(rng)
        ref = decompose_batch(a, "maxweight")
        got = decompose_batch(a, "maxweight", backend="jax")
        for d_ref, d_got in zip(ref, got):
            assert d_got.meta["lap_backend"] == "jax"
            assert d_got.num_phases == d_ref.num_phases
            sp_ref, sp_got = d_ref.stacked(), d_got.stacked()
            # zero-residual rows admit many equal-weight matchings, so
            # perms are compared only where tokens actually move
            np.testing.assert_allclose(sp_got.sent, sp_ref.sent)
            moving = sp_ref.sent > 0
            np.testing.assert_array_equal(
                sp_got.perms[moving], sp_ref.perms[moving]
            )

    def test_jax_backend_respects_link_mask(self):
        rng = np.random.default_rng(37)
        a = self._unique_stack(rng, L=2, n=6)
        mask = np.ones((6, 6), bool)
        mask[0, 1] = mask[3, 2] = False
        out = decompose_batch(a, "maxweight", backend="jax", link_mask=mask)
        for d in out:
            assert d.meta.get("link_masked")
            sp = d.stacked()
            for k in range(sp.num_phases):
                on = sp.sent[k] > 0
                assert mask[np.arange(6)[on], sp.perms[k][on]].all()

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            decompose_batch(np.zeros((1, 4, 4)), "maxweight", backend="tpu")


# ------------------------------------------------------------ traced twins
class TestTracedTwins:
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_link_mask_parity_with_host(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 9))
        a = rng.random((n, n)) * 300.0
        np.fill_diagonal(a, rng.random(n) * 50.0)
        mask = rng.random((n, n)) < 0.6
        np.fill_diagonal(mask, True)
        want = apply_link_mask(a, mask)
        got = np.asarray(apply_link_mask_traced(a, mask))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_link_mask_traced_idempotent_and_batched(self):
        rng = np.random.default_rng(41)
        a = rng.random((3, 5, 5)) * 100.0
        mask = rng.random((5, 5)) < 0.5
        np.fill_diagonal(mask, True)
        once = np.asarray(apply_link_mask_traced(a, mask))
        twice = np.asarray(apply_link_mask_traced(once, mask))
        np.testing.assert_allclose(twice, once, rtol=1e-5, atol=1e-5)
        for l in range(3):
            np.testing.assert_allclose(
                once[l], apply_link_mask(a[l], mask), rtol=1e-5, atol=1e-5
            )

    @pytest.mark.parametrize("n_src", [1, N, 2 * N])
    def test_routing_fold_parity_with_host(self, n_src):
        rng = np.random.default_rng(43)
        stats = rng.integers(0, 50, size=(3, n_src, E)).astype(np.float64)
        want = routing_to_traffic(stats, n_ranks=N, n_experts=E)
        got = np.asarray(
            routing_to_traffic_traced(stats, n_ranks=N, n_experts=E)
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)


# -------------------------------------------------------- device controller
def _runtime(L=2, **cfg_kw):
    kw = dict(n_ranks=N, n_experts=E, ema=1.0, cooldown=0)
    kw.update(cfg_kw)
    return ScheduleRuntime(ControllerConfig(**kw), L)


def _stats_of(traffic):
    """[L, n, n] rank traffic -> [L, n, E] routing counts folding back to
    exactly that traffic (each rank's share split over its experts)."""
    t = np.asarray(traffic, dtype=np.float64)
    L, n, _ = t.shape
    e_local = E // n
    stats = np.repeat(t / e_local, e_local, axis=2)
    np.testing.assert_allclose(
        routing_to_traffic(stats, n_ranks=n, n_experts=E), t
    )
    return stats


def _hot_traffic(L=2, hot=3, scale=600.0):
    """Hotspot column traffic: everything wants rank ``hot``."""
    t = np.full((L, N, N), 4.0)
    t[:, :, hot] = scale
    for l in range(L):
        np.fill_diagonal(t[l], 0.0)
    return t


def _flat_traffic(L=2, scale=100.0):
    t = np.full((L, N, N), scale)
    for l in range(L):
        np.fill_diagonal(t[l], 0.0)
    return t


class TestDeviceController:
    def test_from_runtime_adopts_table_and_policy(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state = DeviceController.from_runtime(rt)
        tbl = rt.table()
        dev = ctrl.table_of(state)
        np.testing.assert_array_equal(np.asarray(dev.perms), np.asarray(tbl.perms))
        np.testing.assert_array_equal(np.asarray(dev.caps), np.asarray(tbl.caps))
        np.testing.assert_array_equal(np.asarray(dev.valid), np.asarray(tbl.valid))
        assert dev.envelope == tbl.envelope
        assert ctrl.cfg.ema == rt.cfg.ema
        assert ctrl.cfg.drop_tolerance == rt.cfg.drop_tolerance
        assert int(state.steps) == 1  # primed EMA counts as an observation

    def test_steady_state_never_replans(self):
        rt = _runtime()
        flat = _flat_traffic()
        rt.prime(flat[0])
        ctrl, state = DeviceController.from_runtime(rt)
        stats = _stats_of(flat)
        for _ in range(8):
            state = ctrl.step(state, stats)
        m = ctrl.metrics(state)
        assert m["device_replans"] == 0
        assert m["drop_fraction"] <= ctrl.cfg.drop_tolerance

    def test_drift_fires_in_graph_replan_and_absorbs_it(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state = DeviceController.from_runtime(rt, hysteresis_steps=2)
        stats = _stats_of(_hot_traffic())
        for _ in range(4):
            state = ctrl.step(state, stats)
        m = ctrl.metrics(state)
        assert m["device_replans"] >= 1
        # the re-planned table absorbs the hotspot: drop back under tol
        assert m["drop_fraction"] <= ctrl.cfg.drop_tolerance

    def test_hysteresis_counts_consecutive_steps(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state = DeviceController.from_runtime(rt, hysteresis_steps=3)
        hot = _stats_of(_hot_traffic())
        state = ctrl.step(state, hot)  # streak 1
        assert ctrl.metrics(state)["device_replans"] == 0
        state = ctrl.step(state, hot)  # streak 2
        assert ctrl.metrics(state)["device_replans"] == 0
        state = ctrl.step(state, hot)  # streak 3 -> fires
        assert ctrl.metrics(state)["device_replans"] == 1

    def test_cooldown_blocks_refire(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state = DeviceController.from_runtime(
            rt, hysteresis_steps=1, cooldown=50
        )
        # alternate hotspots so drift pressure persists after each re-plan
        a = _stats_of(_hot_traffic(hot=3))
        b = _stats_of(_hot_traffic(hot=0))
        state = ctrl.step(state, a)
        assert ctrl.metrics(state)["device_replans"] == 1
        for i in range(6):
            state = ctrl.step(state, b if i % 2 == 0 else a)
        assert ctrl.metrics(state)["device_replans"] == 1  # cooldown holds

    def test_stepping_is_one_executable(self):
        """Steady and drift steps (the re-plan included) share one
        compiled step — the cond is data, not structure."""
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state = DeviceController.from_runtime(rt, hysteresis_steps=1)
        step = jax.jit(ctrl.step)
        flat = jnp.asarray(_stats_of(_flat_traffic()))
        hot = jnp.asarray(_stats_of(_hot_traffic()))
        for _ in range(3):
            state = step(state, flat)
        state = step(state, hot)
        state = step(state, hot)
        assert ctrl.metrics(state)["device_replans"] >= 1
        assert step._cache_size() == 1

    def test_set_link_mask_replans_off_dark_pairs(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state = DeviceController.from_runtime(rt)
        mask = np.ones((N, N), bool)
        mask[0, 2] = mask[2, 0] = False
        state = ctrl.set_link_mask(state, mask)
        m = ctrl.metrics(state)
        assert m["device_replans"] == 1 and m["link_masked"]
        perms = np.asarray(state.perms)
        valid = np.asarray(state.valid)
        L, K, _ = perms.shape
        for l in range(L):
            for k in range(K):
                on = valid[l, k]
                assert mask[np.arange(N)[on], perms[l, k][on]].all()
        # scoring after the mask uses the rerouted demand: steady flat
        # traffic stays under tolerance on the masked plan
        state = ctrl.step(state, _stats_of(_flat_traffic()))
        assert ctrl.metrics(state)["drop_fraction"] <= ctrl.cfg.drop_tolerance

    def test_metrics_is_plain_host_telemetry(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state = DeviceController.from_runtime(rt)
        m = ctrl.metrics(state)
        assert set(m) == {
            "steps", "device_replans", "drop_fraction", "drift_streak",
            "cooldown_left", "drop_spikes", "admitted_dropped", "link_masked",
            "regime_library_size", "regime_warm_swaps",
        }
        assert isinstance(m["steps"], int)
        assert isinstance(m["drop_fraction"], float)
        assert m["link_masked"] is False

    def test_state_is_a_pytree_with_array_leaves(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        _, state = DeviceController.from_runtime(rt)
        leaves = jax.tree.leaves(state)
        assert len(leaves) == len(dataclasses.fields(state))
        roundtrip = jax.tree.unflatten(jax.tree.structure(state), leaves)
        assert isinstance(roundtrip, type(state))


class TestDeviceTrainLoop:
    def test_device_controller_rides_the_fused_step(self, tmp_path):
        """End to end: the in-graph loop absorbs router drift with zero
        recompiles and zero per-step host fetches of routing stats."""
        from test_schedule_table import N_V, _moe_cfg

        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = _moe_cfg(n_layers=2)
        model = Model(cfg)
        rt = ScheduleRuntime(
            ControllerConfig(n_ranks=N_V, n_experts=8, ema=1.0, cooldown=2),
            model.n_moe_layers,
        )
        tokens = 8 * 32 * 2
        rt.prime(np.full((N_V, N_V), tokens / N_V**2))
        ctrl, state0 = DeviceController.from_runtime(rt, hysteresis_steps=1)
        res = train_loop(
            model,
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8),
            TrainLoopConfig(
                steps=10, ckpt_dir=str(tmp_path), ckpt_every=20,
                peak_lr=1e-3, warmup=4, log_every=5,
            ),
            device_controller=ctrl,
            device_ctrl_state=state0,
        )
        ctl = res["controller"]
        assert ctl["mode"] == "device"
        assert ctl["compiles"] == 0, ctl
        assert ctl["steps"] == 10 + 1, ctl  # primed state counts step 0
        assert np.isfinite(res["final_loss"])
        assert "device_ctrl_state" in res
        # telemetry rides the logging cadence, not the step
        assert all("device_replans" in h for h in res["history"])
        assert all("drop_fraction" in h for h in res["history"])

    def test_device_mode_validation(self):
        from test_schedule_table import N_V, _moe_cfg

        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = _moe_cfg(n_layers=2)
        model = Model(cfg)
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl, state0 = DeviceController.from_runtime(rt)
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
        loop = TrainLoopConfig(steps=2, ckpt_dir="/tmp/x", ckpt_every=20)
        with pytest.raises(ValueError, match="mutually exclusive"):
            train_loop(
                model, data, loop,
                runtime=rt, device_controller=ctrl, device_ctrl_state=state0,
            )
        with pytest.raises(ValueError, match="initial state"):
            train_loop(model, data, loop, device_controller=ctrl)


# ------------------------------------------------------ schedule regime bank
def _regime_ctrl(**cfg_kw):
    """Flat-primed controller with an (empty) 2-slot regime library."""
    rt = _runtime()
    rt.prime(_flat_traffic()[0])
    kw = dict(hysteresis_steps=1, cooldown=0, regime_slots=2,
              regime_threshold=0.25)
    kw.update(cfg_kw)
    return DeviceController.from_runtime(rt, **kw)


def _hot_regime_entry(ctrl, state):
    """Cold-solve the hotspot regime once and snapshot (table, reference)
    — the capture pattern the serving engine uses."""
    hot = _stats_of(_hot_traffic())
    s = state
    for _ in range(3):
        s = ctrl.step(s, hot)
    assert ctrl.metrics(s)["device_replans"] >= 1
    tab = jax.tree.map(np.asarray, ctrl.table_of(s))
    ref = np.asarray(s.smoothed).mean(axis=0)
    return tab, ref, hot


class TestRegimeLibrary:
    def test_load_regimes_validation(self):
        rt = _runtime()
        rt.prime(_flat_traffic()[0])
        ctrl0, state0 = DeviceController.from_runtime(rt)
        tab = jax.tree.map(np.asarray, ctrl0.table_of(state0))
        ref = _flat_traffic()[0]
        with pytest.raises(ValueError, match="regime_slots"):
            ctrl0.load_regimes(state0, [tab], [ref])
        ctrl, state = _regime_ctrl()
        with pytest.raises(ValueError, match="tables vs"):
            ctrl.load_regimes(state, [tab], [ref, ref])
        with pytest.raises(ValueError, match="exceed regime_slots"):
            ctrl.load_regimes(state, [tab] * 3, [ref] * 3)
        with pytest.raises(ValueError, match="reference shape"):
            ctrl.load_regimes(state, [tab], [np.ones((N + 1, N + 1))])
        loaded = ctrl.load_regimes(state, [tab], [ref])
        assert ctrl.metrics(loaded)["regime_library_size"] == 1

    def test_warm_swap_replays_stored_plan_bit_identical(self):
        ctrl, state = _regime_ctrl()
        tab, ref, hot = _hot_regime_entry(ctrl, state)
        state = ctrl.load_regimes(state, [tab], [ref])
        for _ in range(3):
            state = ctrl.step(state, hot)
        m = ctrl.metrics(state)
        assert m["regime_warm_swaps"] >= 1
        np.testing.assert_array_equal(np.asarray(state.perms), tab.perms)
        np.testing.assert_array_equal(np.asarray(state.caps), tab.caps)
        np.testing.assert_array_equal(np.asarray(state.valid), tab.valid)
        np.testing.assert_array_equal(
            np.asarray(state.n_phases), tab.n_phases
        )
        # the warm plan absorbs the regime it was planned for
        assert m["drop_fraction"] <= ctrl.cfg.drop_tolerance

    def test_unrecognized_regime_cold_solves(self):
        # library holds only the FLAT regime; hotspot traffic is far from
        # it in shape, so the fire must take the cold branch
        ctrl, state = _regime_ctrl(regime_threshold=0.05)
        flat_tab = jax.tree.map(np.asarray, ctrl.table_of(state))
        state = ctrl.load_regimes(
            state, [flat_tab], [_flat_traffic()[0]]
        )
        hot = _stats_of(_hot_traffic())
        for _ in range(3):
            state = ctrl.step(state, hot)
        m = ctrl.metrics(state)
        assert m["device_replans"] >= 1
        assert m["regime_warm_swaps"] == 0
        # and the cold solve absorbed the hotspot anyway
        assert m["drop_fraction"] <= ctrl.cfg.drop_tolerance

    def test_degraded_link_mask_disables_warm_matching(self):
        # stored plans were routed for the healthy fabric: with a dark
        # link the fire must re-solve under the mask, not warm-swap
        ctrl, state = _regime_ctrl()
        tab, ref, hot = _hot_regime_entry(ctrl, state)
        state = ctrl.load_regimes(state, [tab], [ref])
        mask = np.ones((N, N), bool)
        mask[0, 1] = mask[1, 0] = False
        state = ctrl.set_link_mask(state, mask)
        replans0 = ctrl.metrics(state)["device_replans"]
        for _ in range(3):
            state = ctrl.step(state, hot)
        m = ctrl.metrics(state)
        assert m["device_replans"] > replans0
        assert m["regime_warm_swaps"] == 0

    def test_replan_penalty_blocks_cold_but_not_warm(self):
        hot = _stats_of(_hot_traffic())
        # penalty above any achievable drop saving: cold fires are never
        # worth the dark window, so the controller rides the stale plan
        ctrl, state = _regime_ctrl(replan_penalty=0.99)
        for _ in range(4):
            state = ctrl.step(state, hot)
        m = ctrl.metrics(state)
        assert m["device_replans"] == 0
        assert m["drop_fraction"] > ctrl.cfg.drop_tolerance  # pressure real
        # a warm swap rides pre-established circuits (no dark window):
        # the same penalty does not block it
        ctrl2, state2 = _regime_ctrl(replan_penalty=0.99)
        tab, ref, _ = _hot_regime_entry(_regime_ctrl()[0], _regime_ctrl()[1])
        state2 = ctrl2.load_regimes(state2, [tab], [ref])
        for _ in range(4):
            state2 = ctrl2.step(state2, hot)
        m2 = ctrl2.metrics(state2)
        assert m2["regime_warm_swaps"] >= 1
        assert m2["device_replans"] >= 1
