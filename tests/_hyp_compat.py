"""Minimal hypothesis stand-in so property tests still run (not skip)
when the real ``hypothesis`` package is unavailable.

Provides just the surface this suite uses — ``given``/``settings`` and
``strategies.integers/floats`` — backed by a deterministic RNG sweep.
Install ``hypothesis`` (see requirements-dev.txt) to get real shrinking
and example databases; this fallback trades those for zero dependencies.

Usage (in test modules):

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from _hyp_compat import given, settings
        from _hyp_compat import strategies as st
"""

from __future__ import annotations

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class strategies:  # mirrors ``hypothesis.strategies`` naming
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value, endpoint=True))
        )

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value))
        )


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator recording how many examples ``given`` should run."""

    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    """Run the wrapped test over a deterministic sweep of drawn examples."""

    def deco(fn):
        def runner(*args):
            n = getattr(fn, "_hyp_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(1234)
            for i in range(n):
                drawn = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*args, *drawn)
                except Exception as e:  # re-raise with the failing example
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}"
                    ) from e

        # NOT functools.wraps: pytest must see runner's bare (*args)
        # signature, not the wrapped one's drawn parameters (it would
        # treat them as fixtures).
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco
