"""Fabric API (PR 5): the registry, the error paths, and the
cross-fabric parity matrix.

The real EP movement is exercised on an 8-device mesh in
``tests/multidev_fabric.py`` (slow lane); everything here runs on one
device, where every mesh backend resolves through the shared *virtual*
dense fallback — which is itself part of the parity matrix: all
registered fabrics must agree on values, grads, and the
``{routing, dropped}`` stats contract because they share one pipeline
and one geometry module, and the single-device virtual fabric must
execute a traced row's admission semantics identically to the pair-caps
oracle.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoECfg
from repro.core import (
    ScheduleTable,
    decompose,
    hierarchical_plan,
    plan_schedule,
)
from repro.models import moe
from repro.parallel.fabric import (
    FABRICS,
    consumes_schedule,
    fabric_names,
    get_fabric,
    resolve_fabric,
)

N_V = 4
ALL_FABRICS = ("dense", "a2a", "ppermute", "phase_pipelined", "ragged_a2a")


def _cfg(dispatch: str = "dense", **moe_kw):
    kw = dict(
        n_experts=8, top_k=2, d_ff_expert=32, dispatch=dispatch,
        capacity_factor=8.0,
    )
    kw.update(moe_kw)
    return ModelConfig(
        name="fabric-test",
        family="moe",
        n_layers=1,
        d_model=32,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=128,
        moe=MoECfg(**kw),
        remat="none",
    )


def _plan(seed: int, scale: float = 400.0, n: int = N_V):
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) * scale
    np.fill_diagonal(m, 0)
    return plan_schedule(decompose(m, "maxweight"))


def _row(seed: int = 0, envelope="auto"):
    return ScheduleTable.from_schedules(
        [_plan(seed)], k_max=N_V, envelope=envelope
    ).row(0)


def _htraffic(seed: int = 2, scale: float = 400.0, n: int = N_V):
    rng = np.random.default_rng(seed)
    m = rng.random((n, n)) * scale
    np.fill_diagonal(m, 0)
    return m


def _hrow(pod_size: int = 2, seed: int = 2):
    return hierarchical_plan(_htraffic(seed), pod_size, n_layers=1).row(0)


class TestRegistry:
    def test_all_five_registered(self):
        assert set(ALL_FABRICS) <= set(fabric_names())

    def test_unknown_dispatch_lists_registered_names(self):
        """Satellite: the error names every registered fabric."""
        with pytest.raises(ValueError) as e:
            get_fabric("photonic_tbd")
        msg = str(e.value)
        for name in fabric_names():
            assert name in msg, f"{name} missing from: {msg}"
        assert "scheduled" in msg  # the alias is documented too

    def test_moe_apply_unknown_dispatch(self):
        cfg = _cfg("warp_drive")
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jnp.zeros((1, 4, 32), jnp.float32)
        with pytest.raises(ValueError, match="registered fabrics"):
            moe.moe_apply(params, cfg, x)

    def test_scheduled_alias_resolution(self):
        from repro.parallel.fabric import (
            PhasePipelinedFabric,
            PPermuteFabric,
        )

        assert isinstance(
            resolve_fabric("scheduled", _plan(0)), PPermuteFabric
        )
        assert isinstance(
            resolve_fabric("scheduled", _row()), PhasePipelinedFabric
        )
        with pytest.raises(ValueError, match="A2ASchedule or ScheduleTable"):
            resolve_fabric("scheduled", None)

    def test_consumes_schedule_capabilities(self):
        from repro.parallel.fabric import consumes_table

        assert not consumes_schedule("dense")
        assert not consumes_schedule("a2a")
        for name in ("ppermute", "phase_pipelined", "ragged_a2a", "scheduled"):
            assert consumes_schedule(name), name
        # ppermute needs a schedule but cannot take the controller's
        # traced rows (plans are baked into its executable)
        assert not consumes_table("ppermute")
        for name in ("phase_pipelined", "ragged_a2a", "scheduled"):
            assert consumes_table(name), name
        with pytest.raises(ValueError, match="registered fabrics"):
            consumes_schedule("warp_drive")

    def test_as_fabric_schedule_adapts_static_plans(self):
        from repro.parallel.fabric import as_fabric_schedule

        plan = _plan(0)
        assert as_fabric_schedule("ppermute", plan, 3) is plan
        assert as_fabric_schedule("scheduled", plan, 3) is plan
        t = as_fabric_schedule("ragged_a2a", plan, 3)
        assert isinstance(t, ScheduleTable)
        assert t.num_layers == 3 and t.envelope is not None
        assert as_fabric_schedule("phase_pipelined", t, 3) is t

    def test_train_loop_refuses_runtime_for_static_fabric(self):
        """A controller runtime cannot swap a baked-in ppermute plan —
        the loop must refuse up front, naming the traced alternatives,
        instead of trace-failing max_failures+1 times."""
        from repro.core import ControllerConfig, ScheduleRuntime
        from repro.data import DataConfig
        from repro.models import Model
        from repro.train import TrainLoopConfig, train_loop

        cfg = _cfg("ppermute")
        model = Model(cfg)
        rt = ScheduleRuntime(
            ControllerConfig(n_ranks=N_V, n_experts=8), model.n_moe_layers
        )
        rt.prime(np.full((N_V, N_V), 100.0))
        with pytest.raises(ValueError, match="phase_pipelined"):
            train_loop(
                model,
                DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=2),
                TrainLoopConfig(steps=1, ckpt_dir="/tmp/fab_pp_ck"),
                runtime=rt,
            )


class TestScheduleMisuse:
    """Satellite: row/schedule misuse errors name the rejecting backend."""

    def test_ppermute_rejects_row_by_name(self):
        with pytest.raises(ValueError, match="ppermute"):
            get_fabric("ppermute").validate_schedule(_row(), n=N_V)

    def test_ppermute_requires_schedule(self):
        with pytest.raises(ValueError, match="ppermute"):
            get_fabric("ppermute").validate_schedule(None, n=N_V)

    def test_row_backends_reject_static_schedule_by_name(self):
        for name in ("phase_pipelined", "ragged_a2a"):
            with pytest.raises(ValueError, match=name):
                get_fabric(name).validate_schedule(_plan(0), n=N_V)

    def test_row_backends_reject_full_table_by_name(self):
        table = ScheduleTable.from_schedules([_plan(0), _plan(1)], k_max=N_V)
        for name in ("phase_pipelined", "ragged_a2a"):
            with pytest.raises(ValueError, match=name):
                get_fabric(name).validate_schedule(table, n=N_V)

    def test_rank_mismatch_names_backend(self):
        row = _row()
        with pytest.raises(ValueError, match="phase_pipelined.*4 ranks"):
            get_fabric("phase_pipelined").validate_schedule(row, n=8)

    def test_ragged_requires_envelope(self):
        with pytest.raises(ValueError, match="ragged_a2a.*envelope"):
            get_fabric("ragged_a2a").validate_schedule(
                _row(envelope=None), n=N_V
            )

    def test_moe_apply_still_rejects_full_table(self):
        cfg = _cfg("phase_pipelined")
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        table = ScheduleTable.from_schedules([_plan(0)], k_max=N_V)
        with pytest.raises(ValueError, match="row"):
            moe.moe_apply(params, cfg, jnp.zeros((1, 4, 32)), schedule=table)

    def test_errors_name_the_fallback_fabric(self):
        """PR 6 satellite: every schedule-rejection error states the next
        fabric in the degradation chain, so a failing config tells the
        operator what to fall back to without a docs round-trip."""
        from repro.parallel.fabric import DEGRADATION_CHAIN, next_fabric

        cases = [
            ("ppermute", _row()),
            ("phase_pipelined", _plan(0)),
            ("ragged_a2a", _plan(0)),
            ("ragged_a2a", _row(envelope=None)),
        ]
        for name, bad in cases:
            with pytest.raises(ValueError) as e:
                get_fabric(name).validate_schedule(bad, n=N_V)
            nxt = next_fabric(name)
            assert nxt in DEGRADATION_CHAIN
            assert f"next fabric is {nxt!r}" in str(e.value), (name, str(e.value))

    def test_end_of_chain_says_so(self):
        """dense is the chain's floor: its rejections must say there is
        nowhere left to fall."""
        table = ScheduleTable.from_schedules([_plan(0), _plan(1)], k_max=N_V)
        with pytest.raises(ValueError, match="end of degradation chain"):
            get_fabric("dense").validate_schedule(table, n=N_V)


class TestParityMatrixSingleDevice:
    """The parity matrix on one device: every registered fabric resolves
    through the shared virtual dense fallback, so values, grads, and the
    stats contract must agree bit-for-bit across all of them — and with
    the explicit dense oracle."""

    def setup_method(self):
        self.x = jax.random.normal(
            jax.random.PRNGKey(1), (4, 32, 32), jnp.float32
        )
        self.params = moe.moe_init(jax.random.PRNGKey(0), _cfg())

    def _sched_for(self, name):
        if name in ("phase_pipelined", "ragged_a2a"):
            return _row(seed=2)
        if name == "ppermute":
            return _plan(2)
        return None

    @pytest.mark.parametrize("name", ALL_FABRICS)
    def test_values_grads_stats_match_dense(self, name):
        cfg = _cfg(name)
        y_ref, st_ref = moe._moe_dense(
            self.params, _cfg(), self.x, return_stats=True
        )
        y, st = moe.moe_apply(
            self.params, cfg, self.x, schedule=self._sched_for(name),
            return_stats=True,
        )
        if name in ("phase_pipelined", "ragged_a2a"):
            # the row clips gates on the virtual fabric: compare against
            # the dense oracle given the SAME row
            y_ref, st_ref = moe._moe_dense(
                self.params, _cfg(), self.x, self._sched_for(name),
                return_stats=True,
            )
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref))
        assert set(st) == {"routing", "dropped"}  # the stats contract
        assert st["routing"].shape == (1, 8)
        assert st["dropped"].shape == (1,)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        g = jax.grad(
            lambda p: (moe.moe_apply(
                p, cfg, self.x, schedule=self._sched_for(name)
            ) ** 2).sum()
        )(self.params)
        g_ref = jax.grad(
            lambda p: (moe._moe_dense(
                p, _cfg(), self.x,
                self._sched_for(name)
                if name in ("phase_pipelined", "ragged_a2a")
                else None,
            ) ** 2).sum()
        )(self.params)
        for ga, gr in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(ga), np.asarray(gr))

    def test_row_fabrics_agree_with_each_other(self):
        """phase_pipelined and ragged_a2a share geometry by construction;
        the virtual fallback must not break that."""
        row = _row(seed=3)
        outs = [
            moe.moe_apply(
                self.params, _cfg(name), self.x, schedule=row,
                return_stats=True,
            )
            for name in ("phase_pipelined", "ragged_a2a")
        ]
        np.testing.assert_allclose(
            np.asarray(outs[0][0]), np.asarray(outs[1][0])
        )
        for a, b in zip(
            jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[1][1])
        ):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_virtual_fabric_row_clips_like_pair_caps_oracle(self):
        """The single-device virtual fabric executes the row's admission
        exactly as pair_caps promises (a tight plan must bind)."""
        tiny = np.full((N_V, N_V), 1.0)
        np.fill_diagonal(tiny, 0)
        row = ScheduleTable.from_schedules(
            [plan_schedule(decompose(tiny, "maxweight"), min_cap=1, quantum=1)]
        ).row(0)
        for name in ("phase_pipelined", "scheduled"):
            y_row = moe.moe_apply(
                self.params, _cfg(name), self.x, schedule=row
            )
            y_free = moe._moe_dense(self.params, _cfg(), self.x)
            assert not np.allclose(
                np.asarray(y_row), np.asarray(y_free), atol=1e-6
            ), name


class TestHierarchicalSingleDevice:
    """PR 9: the composed fabric's single-device leg of the parity
    matrix.  On one device ``hierarchical`` resolves through the same
    virtual dense fallback as the flat traced fabrics, reading admission
    from the HierarchicalTable's summed per-level pair caps and the wire
    mask from the pod seam — values, grads, and the stats contract must
    match the dense oracle handed the same composed row."""

    def setup_method(self):
        self.x = jax.random.normal(
            jax.random.PRNGKey(1), (4, 32, 32), jnp.float32
        )
        self.params = moe.moe_init(jax.random.PRNGKey(0), _cfg())

    @pytest.mark.parametrize("pod_size", (2, 4))
    def test_values_grads_stats_match_dense_oracle(self, pod_size):
        row = _hrow(pod_size)
        cfg = _cfg("hierarchical", pod_size=pod_size)
        y, st = moe.moe_apply(
            self.params, cfg, self.x, schedule=row, return_stats=True
        )
        y_ref, st_ref = moe._moe_dense(
            self.params, _cfg(), self.x, row, return_stats=True
        )
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref))
        assert set(st) == {"routing", "dropped"}
        assert st["routing"].shape == (1, 8)
        assert st["dropped"].shape == (1,)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
        g = jax.grad(
            lambda p: (
                moe.moe_apply(p, cfg, self.x, schedule=row) ** 2
            ).sum()
        )(self.params)
        g_ref = jax.grad(
            lambda p: (moe._moe_dense(p, _cfg(), self.x, row) ** 2).sum()
        )(self.params)
        for ga, gr in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
            np.testing.assert_allclose(np.asarray(ga), np.asarray(gr))

    def test_admission_binds_like_flat_row(self):
        """A tight two-level plan must clip gates: the composed table's
        summed per-level pair caps feed the same admission mask the flat
        row fabrics use."""
        tiny = np.full((N_V, N_V), 1.0)
        np.fill_diagonal(tiny, 0)
        row = hierarchical_plan(
            tiny, 2, n_layers=1, min_cap=1, quantum=1
        ).row(0)
        y_row = moe.moe_apply(
            self.params, _cfg("hierarchical"), self.x, schedule=row
        )
        y_free = moe._moe_dense(self.params, _cfg(), self.x)
        assert not np.allclose(
            np.asarray(y_row), np.asarray(y_free), atol=1e-6
        )

    def test_wire_crosses_only_the_pod_seam(self):
        """fp8 quantizes only inter-pod slots: one pod covering every
        rank makes the codec a bit-exact no-op, two pods engage it
        within the documented tolerance, and routing/drop stats stay
        bit-identical either way (admission precedes the codec)."""
        row4 = _hrow(4)
        y4 = moe.moe_apply(
            self.params, _cfg("hierarchical", pod_size=4), self.x,
            schedule=row4,
        )
        y4_q = moe.moe_apply(
            self.params,
            _cfg("hierarchical", pod_size=4, wire_dtype="fp8"),
            self.x, schedule=row4,
        )
        np.testing.assert_array_equal(np.asarray(y4_q), np.asarray(y4))
        row2 = _hrow(2)
        y2, st2 = moe.moe_apply(
            self.params, _cfg("hierarchical"), self.x, schedule=row2,
            return_stats=True,
        )
        y2_q, st2_q = moe.moe_apply(
            self.params, _cfg("hierarchical", wire_dtype="fp8"), self.x,
            schedule=row2, return_stats=True,
        )
        err = float(jnp.abs(y2_q - y2).max())
        assert 0.0 < err <= TestWireDtypeParity.VALUE_TOL["fp8"], err
        for a, b in zip(jax.tree.leaves(st2_q), jax.tree.leaves(st2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestBytesAccounting:
    """Per-fabric ``dispatch_tokens``: the acceptance ordering —
    ragged == phase-pipelined == live envelope bytes, strictly below
    both the dense-emulation padded figure (the emulation tax, reported
    separately via ``dispatch_tokens_padded``) and the monolithic a2a
    bucket on a skewed plan."""

    def test_ordering_on_skewed_plan(self):
        from repro.core.cost_models import phase_dispatch_tokens

        rng = np.random.default_rng(11)
        n = 8
        m = rng.random((n, n))
        m[0, 1] = 60.0  # one hot pair, many near-dark ones
        np.fill_diagonal(m, 0)
        sched = plan_schedule(decompose(m, "maxweight", min_fill=0.1))
        from repro.core.schedule import phase_envelope

        env = phase_envelope([sched], sched.num_phases, slack=1.5)
        cap_uni = 64
        cap_nodrop = max(cap_uni, sched.pair_capacity())
        a2a = get_fabric("a2a").dispatch_tokens(n=n, cap_uniform=cap_nodrop)
        ragged = get_fabric("ragged_a2a").dispatch_tokens(
            n=n, schedule=sched, envelope=env
        )
        live = get_fabric("phase_pipelined").dispatch_tokens(
            n=n, schedule=sched, envelope=env
        )
        emul = get_fabric("phase_pipelined").dispatch_tokens_padded(
            n=n, envelope=env
        )
        static = get_fabric("ppermute").dispatch_tokens(n=n, schedule=sched)
        dense = get_fabric("dense").dispatch_tokens(n=n)
        # both traced fabrics carry exactly the live envelope bytes
        assert ragged == pytest.approx(
            float(np.mean(phase_dispatch_tokens(sched.valid, env)))
        )
        assert live == ragged
        assert dense == 0.0
        assert static <= ragged < emul
        assert ragged < a2a, (ragged, a2a)


class TestRaggedFallback:
    def test_fallback_is_emulation_off_tpu(self):
        from repro.parallel.fabric import ragged_available

        # in this container (pinned jax, CPU) the primitive is absent:
        # the backend must run the parent's dense emulation
        import jax as _jax

        if getattr(_jax.lax, "ragged_all_to_all", None) is None:
            assert not ragged_available()


class TestEnvelopeShrink:
    """Satellite: ControllerConfig.envelope_decay — sustained underuse
    shrinks the envelope; a shrink is the one counted recompile."""

    def _runtime(self, decay, patience=2):
        from repro.core import ControllerConfig, ScheduleRuntime

        return ScheduleRuntime(
            ControllerConfig(
                n_ranks=N_V, n_experts=8, ema=1.0, cooldown=0,
                envelope_slack=1.5, envelope_decay=decay,
                shrink_patience=patience,
            ),
            1,
        )

    @staticmethod
    def _hot_prime():
        """A hot-column regime: rank 0's experts soak ~4000 tokens/pair,
        everything else trickles — the envelope is sized for the spike."""
        m = np.full((N_V, N_V), 10.0)
        m[:, 0] = 4000.0
        np.fill_diagonal(m, 0.0)
        return m

    def _drive(self, rt, scale, steps, start=0):
        """Cool the regime: the hot expert rotates at a much lower
        scale, so each rotation misses the current plan (the cold
        pair's min-cap slots drop hard) and triggers a rebuild whose
        plans need far less than the primed envelope."""
        for i in range(start, start + steps):
            probs = np.full(8, 0.01)
            # rotate among ranks 1-3 only: revisiting rank 0 would
            # re-adopt the primed hot plan, whose caps legitimately
            # regrow the envelope (plans, not traffic, size buffers)
            probs[[2, 4, 6, 3, 5, 7][i % 6]] = 1.0
            probs /= probs.sum()
            rt.observe(scale * probs[None, None, :])
            rt.table()

    def test_shrink_after_sustained_underuse(self):
        rt = self._runtime(decay=0.5, patience=2)
        rt.prime(self._hot_prime())
        env_hot = rt.table().envelope
        self._drive(rt, scale=400.0, steps=8)  # traffic cools way down
        m = rt.metrics()
        assert m["envelope_shrinks"] >= 1, m
        env_cold = rt.table().envelope
        assert sum(env_cold) < sum(env_hot), (env_hot, env_cold)
        # shrunk slots still cover the current plans (no-drop invariant)
        for s in rt.schedules:
            k = min(s.num_phases, len(env_cold))
            assert (np.asarray(env_cold[:k]) >= np.asarray(s.caps[:k])).all()

    def test_decay_zero_never_shrinks(self):
        rt = self._runtime(decay=0.0)
        rt.prime(self._hot_prime())
        rt.table()
        self._drive(rt, scale=400.0, steps=8)
        assert rt.metrics()["envelope_shrinks"] == 0

    def test_shrink_is_one_recompile(self):
        """The jit cache grows by exactly one when the (static aux)
        envelope shrinks — same contract as a growth."""
        rt = self._runtime(decay=0.5, patience=2)
        rt.prime(self._hot_prime())
        cfg = _cfg("phase_pipelined")
        params = moe.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 16, 32), jnp.float32)
        f = jax.jit(lambda p, x, r: moe.moe_apply(p, cfg, x, schedule=r))
        f(params, x, rt.table().row(0))
        steps = 0
        while rt.metrics()["envelope_shrinks"] == 0 and steps < 12:
            self._drive(rt, scale=400.0, steps=1, start=steps)
            steps += 1
        assert rt.metrics()["envelope_shrinks"] == 1
        f(params, x, rt.table().row(0))
        assert f._cache_size() == 2, "envelope shrink must retrace once"
        f(params, x, rt.table().row(0))
        assert f._cache_size() == 2

    def test_decay_validation(self):
        from repro.core import ControllerConfig

        with pytest.raises(ValueError, match="envelope_decay"):
            ControllerConfig(n_ranks=4, n_experts=8, envelope_decay=1.5)
        with pytest.raises(ValueError, match="shrink_patience"):
            ControllerConfig(
                n_ranks=4, n_experts=8, envelope_decay=0.5,
                shrink_patience=0,
            )

    def test_shrink_targets_window_peak(self):
        """The shrink target is the peak slacked need over the underuse
        window, not the last rebuild's need — every plan the window saw
        still fits the shrunk envelope (no grow/shrink thrash)."""
        rt = self._runtime(decay=0.5, patience=2)
        rt.prime(self._hot_prime())
        rt.table()  # materialize the hot envelope before the cool-down
        self._drive(rt, scale=400.0, steps=8)
        assert rt.metrics()["envelope_shrinks"] >= 1
        env = np.asarray(rt.table().envelope)
        growths_after = rt.envelope_growths
        # replaying the same cooled regime never regrows the envelope
        self._drive(rt, scale=400.0, steps=8)
        assert rt.envelope_growths == growths_after, (
            "post-shrink envelope must cover the cooled regime's plans"
        )
        assert (np.asarray(rt.table().envelope) <= env).all()


class TestWireCodecProperties:
    """PR 8 satellite: dequantize∘quantize properties of the wire codecs
    on adversarial slots — zeros, inf-adjacent magnitudes, single-token
    slots — straight against ``repro.parallel.fabric.codec``."""

    def test_registry_matches_pricing(self):
        from repro.core import WIRE_DTYPES
        from repro.parallel.fabric import CODECS, codec_names, get_codec

        assert set(CODECS) == set(WIRE_DTYPES)
        assert codec_names() == tuple(sorted(CODECS))
        with pytest.raises(ValueError, match="bf16.*fp8.*int8"):
            get_codec("fp4")

    def test_bf16_is_identity_passthrough(self):
        from repro.parallel.fabric import get_codec

        codec = get_codec("bf16")
        assert codec.is_identity
        buf = jnp.ones((3, 4, 8), jnp.bfloat16)
        wire = jnp.ones((3, 4), bool)
        assert codec.apply(buf, wire) is buf  # not merely equal: untouched

    @pytest.mark.parametrize("wire", ["fp8", "int8"])
    def test_maskless_buffer_is_untouched(self, wire):
        from repro.parallel.fabric import get_codec

        buf = jnp.ones((2, 8), jnp.float32)
        assert get_codec(wire).apply(buf, None) is buf

    @pytest.mark.parametrize("wire", ["fp8", "int8"])
    def test_zero_slots_round_trip_exactly(self, wire):
        """All-zero slots (envelope padding) must QDQ to exact zeros —
        the eps scale guard, not a 0/0 NaN."""
        from repro.parallel.fabric import get_codec

        codec = get_codec(wire)
        x = jnp.zeros((3, 5, 32), jnp.float32)
        q, scale = codec.encode(x)
        assert np.isfinite(np.asarray(scale)).all()
        assert (np.asarray(codec.qdq(x)) == 0.0).all()

    def test_int8_error_bounded_by_half_step(self):
        """Symmetric int8: round-off is at most half a quantization step
        of the slot's own amax — per-slot scales mean a hot slot cannot
        wash out a cold one."""
        from repro.parallel.fabric import get_codec

        codec = get_codec("int8")
        rng = np.random.default_rng(0)
        # wildly mixed per-slot magnitudes, including a near-zero slot
        x = rng.normal(size=(6, 32)) * (10.0 ** rng.integers(-4, 4, (6, 1)))
        x = jnp.asarray(x, jnp.float32)
        amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
        err = np.abs(np.asarray(codec.qdq(x)) - np.asarray(x))
        assert (err <= amax / 127.0 * 0.5 + 1e-6).all()

    def test_fp8_error_bounded_by_e4m3_resolution(self):
        """e4m3: half-ulp relative error (2^-4) for normals plus one
        subnormal step of the scaled format near zero."""
        from repro.parallel.fabric import get_codec

        codec = get_codec("fp8")
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(6, 32)) * 3.0, jnp.float32)
        _, scale = codec.encode(x)
        err = np.abs(np.asarray(codec.qdq(x)) - np.asarray(x))
        bound = 0.0625 * np.abs(np.asarray(x)) + np.asarray(scale)
        assert (err <= bound).all()

    @pytest.mark.parametrize("wire", ["fp8", "int8"])
    def test_inf_adjacent_magnitudes_stay_finite(self, wire):
        """Slots touching the f32 range edge (3e38) must survive the
        wire: finite output, signs preserved, no e4m3fn overflow-NaN."""
        from repro.parallel.fabric import get_codec

        codec = get_codec(wire)
        x = jnp.asarray(
            [[3e38, -3e38, 1e-30, 0.0, -1.5, 2.5e37, -7e36, 1.0]],
            jnp.float32,
        )
        y = np.asarray(codec.qdq(x))
        assert np.isfinite(y).all()
        big = np.abs(np.asarray(x)) >= 1e37
        assert (np.sign(y[big]) == np.sign(np.asarray(x)[big])).all()
        # the amax element round-trips within codec resolution
        assert abs(y[0, 0] - 3e38) <= 0.0625 * 3e38

    @pytest.mark.parametrize("wire", ["fp8", "int8"])
    def test_single_token_slots(self, wire):
        """A slot holding one scalar feature (d=1) is its own amax: the
        value maps to the codec's top code and round-trips tightly."""
        from repro.parallel.fabric import get_codec

        codec = get_codec(wire)
        x = jnp.asarray([[3.7], [-0.003], [1e5], [0.0]], jnp.float32)
        y = np.asarray(codec.qdq(x))
        err = np.abs(y - np.asarray(x))
        assert (err <= 0.01 * np.abs(np.asarray(x)) + 1e-9).all()

    @pytest.mark.parametrize("wire", ["fp8", "int8"])
    def test_ste_gradient_is_identity(self, wire):
        """Gradients pass straight through the QDQ seam (STE) — wire
        noise is round-off, not a differentiable transform."""
        from repro.parallel.fabric import get_codec

        codec = get_codec(wire)
        buf = jax.random.normal(jax.random.PRNGKey(0), (4, 8), jnp.float32)
        mask = jnp.asarray([True, False, True, True])
        g = jax.grad(lambda b: (codec.apply(b, mask) * 3.0).sum())(buf)
        np.testing.assert_allclose(np.asarray(g), 3.0)


class TestWireDtypeParity:
    """PR 8: the wire_dtype axis of the parity matrix.  Quantized wires
    must track the bf16 values within the codec's documented tolerance,
    keep routing/drop stats bit-identical (admission precedes the
    codec), and leave fabrics where nothing crosses the wire exact."""

    # documented max-abs tolerance on unit-scale activations (d_model=32
    # MoE outputs; measured ~0.11 / ~0.023 on the seeded draw)
    VALUE_TOL = {"fp8": 0.25, "int8": 0.06}
    # grad tolerance relative to the bf16 grads' own max magnitude
    GRAD_RTOL = {"fp8": 0.08, "int8": 0.03}

    def setup_method(self):
        self.x = jax.random.normal(
            jax.random.PRNGKey(1), (4, 32, 32), jnp.float32
        )
        self.params = moe.moe_init(jax.random.PRNGKey(0), _cfg())

    def _sched_for(self, name):
        if name in ("phase_pipelined", "ragged_a2a"):
            return _row(seed=2)
        if name == "ppermute":
            return _plan(2)
        return None

    @pytest.mark.parametrize("name", ALL_FABRICS)
    @pytest.mark.parametrize("wire", ["fp8", "int8"])
    def test_values_track_bf16_within_codec_tolerance(self, name, wire):
        sched = self._sched_for(name)
        y_ref, st_ref = moe.moe_apply(
            self.params, _cfg(name), self.x, schedule=sched,
            return_stats=True,
        )
        y_q, st_q = moe.moe_apply(
            self.params, _cfg(name, wire_dtype=wire), self.x,
            schedule=sched, return_stats=True,
        )
        err = float(jnp.abs(y_q - y_ref).max())
        assert err <= self.VALUE_TOL[wire], (name, wire, err)
        # admission runs before the codec: routing and drop stats are
        # bit-identical, and the generous-capacity draw stays drop-free
        for a, b in zip(jax.tree.leaves(st_q), jax.tree.leaves(st_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(np.asarray(st_q["dropped"]).sum()) == 0.0
        if name in ("phase_pipelined", "ragged_a2a"):
            # a schedule row marks cross-virtual-rank slots: the codec
            # must actually engage, not silently no-op
            assert err > 0.0, (name, wire)
        else:
            # no wire mask on one device: quantization never touches
            # local traffic, so the output is bit-exact
            assert err == 0.0, (name, wire)

    @pytest.mark.parametrize("name", ["dense", "phase_pipelined"])
    def test_explicit_bf16_wire_is_bit_exact(self, name):
        sched = self._sched_for(name)
        y_def = moe.moe_apply(self.params, _cfg(name), self.x, schedule=sched)
        y_bf16 = moe.moe_apply(
            self.params, _cfg(name, wire_dtype="bf16"), self.x,
            schedule=sched,
        )
        np.testing.assert_array_equal(np.asarray(y_def), np.asarray(y_bf16))

    @pytest.mark.parametrize("wire", ["fp8", "int8"])
    def test_grads_track_bf16_within_tolerance(self, wire):
        """STE grads through the quantized wire stay close to the bf16
        grads (difference is quantization noise times loss curvature)."""
        row = self._sched_for("phase_pipelined")

        def loss(p, cfg):
            return (
                moe.moe_apply(p, cfg, self.x, schedule=row) ** 2
            ).sum()

        g_ref = jax.grad(loss)(self.params, _cfg("phase_pipelined"))
        g_q = jax.grad(loss)(
            self.params, _cfg("phase_pipelined", wire_dtype=wire)
        )
        scale = max(
            float(jnp.abs(g).max()) for g in jax.tree.leaves(g_ref)
        )
        for a, b in zip(jax.tree.leaves(g_q), jax.tree.leaves(g_ref)):
            assert np.isfinite(np.asarray(a)).all()
            err = float(jnp.abs(a - b).max())
            assert err <= self.GRAD_RTOL[wire] * scale, (wire, err, scale)

    def test_unknown_wire_dtype_raises_listing_codecs(self):
        cfg = _cfg("phase_pipelined", wire_dtype="fp4")
        with pytest.raises(ValueError, match="bf16.*fp8.*int8"):
            moe.moe_apply(
                self.params, cfg, self.x, schedule=self._sched_for(
                    "phase_pipelined"
                ),
            )

    def test_row_fabrics_agree_under_quantization(self):
        """phase_pipelined and ragged_a2a share pack geometry AND wire
        masks — their quantized outputs must agree exactly."""
        row = _row(seed=3)
        outs = [
            moe.moe_apply(
                self.params, _cfg(name, wire_dtype="fp8"), self.x,
                schedule=row,
            )
            for name in ("phase_pipelined", "ragged_a2a")
        ]
        np.testing.assert_array_equal(
            np.asarray(outs[0]), np.asarray(outs[1])
        )

    def test_dispatch_bytes_prices_the_wire(self):
        """Fabric.dispatch_bytes = slot count x wire format price: the
        quantized envelope bytes sit at the documented ratio."""
        from repro.core import wire_bytes_per_token

        row_sched = _plan(5, n=8)
        from repro.core.schedule import phase_envelope

        env = phase_envelope([row_sched], row_sched.num_phases, slack=1.5)
        fab = get_fabric("ragged_a2a")
        d_model = 4096
        toks = fab.dispatch_tokens(n=8, schedule=row_sched, envelope=env)
        for w in ("bf16", "fp8", "int8"):
            got = fab.dispatch_bytes(
                d_model=d_model, wire_dtype=w, n=8,
                schedule=row_sched, envelope=env,
            )
            assert got == pytest.approx(
                toks * wire_bytes_per_token(d_model, w)
            )
        bf16 = fab.dispatch_bytes(
            d_model=d_model, wire_dtype="bf16", n=8,
            schedule=row_sched, envelope=env,
        )
        for w in ("fp8", "int8"):
            q = fab.dispatch_bytes(
                d_model=d_model, wire_dtype=w, n=8,
                schedule=row_sched, envelope=env,
            )
            assert q <= 0.55 * bf16, (w, q, bf16)


class TestFabricDocsContract:
    def test_every_fabric_documents_itself(self):
        for name, fab in FABRICS.items():
            assert type(fab).__doc__ or fab.__module__, name
            assert fab.name == name
            assert fab.schedule_kind in (
                "none", "static", "row", "optional_row"
            )
